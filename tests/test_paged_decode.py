"""Paged decode kernels (Pallas scalar-prefetch gather + XLA fallback) vs
the dense/gathered oracles: ragged lengths, GQA, sliding window, softcap,
null-page masking, and SPLS-compacted (pruned) layouts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, BlockCfg
from repro.kernels.paged_decode import paged_flash_decode
from repro.kernels.ref import flash_decode_ref, paged_decode_ref
from repro.models import get_backend
from repro.serving.pager import POS_SENTINEL

jax.config.update("jax_platform_name", "cpu")


def _pool(B=3, KV=2, G=4, Dh=16, N=12, ps=8, P=4, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, KV, G, Dh))
    kp = jax.random.normal(ks[1], (KV, N, ps, Dh))
    vp = jax.random.normal(ks[2], (KV, N, ps, Dh))
    return q, kp, vp


def _contiguous_layout(tables, kv_len, N, ps):
    """pos_pages where slot index == original position (no pruning)."""
    pos = np.full((N, ps), POS_SENTINEL, np.int64)
    for b in range(tables.shape[0]):
        for j in range(tables.shape[1]):
            pg = int(tables[b, j])
            if pg == 0:
                continue
            pos[pg] = j * ps + np.arange(ps)
    return jnp.asarray(pos, jnp.int32)


class TestPagedKernelParity:
    """pallas_paged == xla gather oracle == contiguous dense oracle."""

    @pytest.mark.parametrize("window", [None, 5, 16])
    def test_ragged_gqa(self, window):
        B, KV, G, Dh, N, ps, P = 3, 2, 4, 16, 12, 8, 4
        q, kp, vp = _pool()
        tables = jnp.asarray([[1, 2, 3, 0], [4, 5, 0, 0], [6, 7, 8, 9]],
                             jnp.int32)
        kv_len = jnp.asarray([20, 9, 32], jnp.int32)
        pos = _contiguous_layout(np.asarray(tables), kv_len, N, ps)
        cur = kv_len - 1
        out = paged_flash_decode(q, kp, vp, pos, tables, kv_len, cur,
                                 window=window, interpret=True)
        want = paged_decode_ref(q, kp, vp, pos, tables, kv_len, cur,
                                window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)
        # contiguous layout also matches the dense flash_decode oracle
        S = P * ps
        kd = jnp.moveaxis(kp[:, tables], 1, 0).reshape(B, KV, S, Dh)
        vd = jnp.moveaxis(vp[:, tables], 1, 0).reshape(B, KV, S, Dh)
        want2 = flash_decode_ref(q, kd, vd, cur, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want2),
                                   atol=2e-5)

    def test_softcap(self):
        q, kp, vp = _pool(seed=5)
        tables = jnp.asarray([[1, 2, 3, 0], [4, 5, 0, 0], [6, 7, 8, 9]],
                             jnp.int32)
        kv_len = jnp.asarray([17, 9, 25], jnp.int32)
        pos = _contiguous_layout(np.asarray(tables), kv_len, 12, 8)
        cur = kv_len - 1
        out = paged_flash_decode(q, kp, vp, pos, tables, kv_len, cur,
                                 softcap=30.0, interpret=True)
        want = paged_decode_ref(q, kp, vp, pos, tables, kv_len, cur,
                                softcap=30.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)

    def test_null_page_and_garbage_masked(self):
        """Unwritten slots (incl. the whole null page) must not contribute,
        whatever garbage they hold."""
        q, kp, vp = _pool(seed=3)
        kp = kp.at[:, 0].set(1e6).at[:, 5].set(-1e6)  # null page + a dirty one
        vp = vp.at[:, 0].set(1e6).at[:, 5].set(-1e6)
        tables = jnp.asarray([[1, 2, 0, 0], [3, 4, 5, 0], [6, 7, 8, 9]],
                             jnp.int32)
        # row 1: page 5 allocated but only 1 slot written into it
        kv_len = jnp.asarray([11, 17, 32], jnp.int32)
        pos = _contiguous_layout(np.asarray(tables), kv_len, 12, 8)
        cur = kv_len - 1
        out = paged_flash_decode(q, kp, vp, pos, tables, kv_len, cur,
                                 interpret=True)
        assert np.isfinite(np.asarray(out)).all()
        want = paged_decode_ref(q, kp, vp, pos, tables, kv_len, cur)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)
        # and dirty page 5's single written slot DOES contribute for row 1:
        # perturbing it must change row 1's output
        kp2 = kp.at[:, 5, 0].set(0.0)
        out2 = paged_flash_decode(q, kp2, vp, pos, tables, kv_len, cur,
                                  interpret=True)
        assert not np.allclose(np.asarray(out[1]), np.asarray(out2[1]))

    @pytest.mark.parametrize("window", [None, 6])
    def test_pruned_compacted_layout(self, window):
        """SPLS page pruning: slots hold a *subset* of positions; masks must
        use the original ids, matching a dense oracle with pruned columns
        masked out."""
        B, KV, G, Dh, N, ps = 2, 2, 3, 16, 10, 4
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        q = jax.random.normal(ks[0], (B, KV, G, Dh))
        L = 14  # original positions 0..13; keep a ragged subset per row
        keep = [np.asarray([0, 2, 3, 5, 8, 9, 12, 13]),
                np.asarray([1, 4, 6, 7, 10, 13])]
        kd = jax.random.normal(ks[1], (B, KV, L, Dh))
        vd = jax.random.normal(ks[2], (B, KV, L, Dh))
        P = 3
        tables = np.zeros((B, P), np.int64)
        kp = np.zeros((KV, N, ps, Dh), np.float32)
        vp = np.zeros((KV, N, ps, Dh), np.float32)
        pos = np.full((N, ps), POS_SENTINEL, np.int64)
        next_page = 1
        kv_len = []
        for b, idx in enumerate(keep):
            n = len(idx)
            kv_len.append(n)
            npages = -(-n // ps)
            pages = list(range(next_page, next_page + npages))
            next_page += npages
            tables[b, :npages] = pages
            for i, j in enumerate(idx):
                pg, off = pages[i // ps], i % ps
                kp[:, pg, off] = np.asarray(kd[b, :, j])
                vp[:, pg, off] = np.asarray(vd[b, :, j])
                pos[pg, off] = j
        tables = jnp.asarray(tables, jnp.int32)
        kv_len = jnp.asarray(kv_len, jnp.int32)
        posj = jnp.asarray(pos, jnp.int32)
        cur = jnp.asarray([L - 1, L - 1], jnp.int32)

        out = paged_flash_decode(q, jnp.asarray(kp), jnp.asarray(vp), posj,
                                 tables, kv_len, cur, window=window,
                                 interpret=True)
        # dense oracle: masked softmax over only the kept original columns
        Dh_s = Dh ** -0.5
        want = np.zeros((B, KV, G, Dh), np.float32)
        for b, idx in enumerate(keep):
            m = np.zeros((L,), bool)
            m[idx] = True
            if window is not None:
                m &= (L - 1) - np.arange(L) < window
            s = np.einsum("kgd,kld->kgl", np.asarray(q[b]),
                          np.asarray(kd[b])) * Dh_s
            s = np.where(m[None, None, :], s, -np.inf)
            a = np.exp(s - s.max(-1, keepdims=True))
            a = a / a.sum(-1, keepdims=True)
            want[b] = np.einsum("kgl,kld->kgd", a, np.asarray(vd[b]))
        np.testing.assert_allclose(np.asarray(out), want, atol=2e-5)


class TestPagedBackendRegistry:
    def test_backends_registered_and_agree(self):
        from repro.models import available_backends, resolve_backend
        assert "xla_paged_decode" in available_backends(decode=True,
                                                        paged=True)
        assert "pallas_paged_decode" in available_backends(decode=True,
                                                           paged=True)
        # auto resolution at a paged decode site
        cfg = ArchConfig(period=(BlockCfg(),))
        got = resolve_backend("auto", cfg, L=64, decode=True, paged=True,
                              platform="cpu")
        assert got == "xla_paged_decode"
        got = resolve_backend("auto", cfg, L=64, decode=True, paged=True,
                              platform="tpu")
        assert got == "pallas_paged_decode"
        # a non-paged decode name at a paged site falls through to auto
        got = resolve_backend("pallas_flash_decode", cfg, L=64, decode=True,
                              paged=True, platform="cpu")
        assert got == "xla_paged_decode"

    def test_backend_fns_agree(self):
        cfg = ArchConfig(period=(BlockCfg(),))
        q, kp, vp = _pool(seed=11)
        tables = jnp.asarray([[1, 2, 3, 0], [4, 5, 0, 0], [6, 7, 8, 9]],
                             jnp.int32)
        kv_len = jnp.asarray([20, 9, 32], jnp.int32)
        pos = _contiguous_layout(np.asarray(tables), kv_len, 12, 8)
        cur = kv_len - 1
        kw = dict(pos_pages=pos, tables=tables, kv_len=kv_len, pos=cur,
                  window=7)
        a = get_backend("xla_paged_decode")(cfg, q, kp, vp, **kw)
        b = get_backend("pallas_paged_decode")(cfg, q, kp, vp, **kw)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
