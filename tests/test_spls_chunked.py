"""Tests for the progressive (chunked) SPLS path used at long sequence
lengths: plan equivalence vs the dense builder, execution semantics, and
the bisection top-k threshold."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.similarity import local_similarity
from repro.core.sparse_exec import gather_rows, spls_attention_chunked
from repro.core.spls_chunked import ChunkedPlan, chunked_plan_scan
from repro.core.topk import sparsify_pam, topk_count

jax.config.update("jax_platform_name", "cpu")


def _heads(B=2, KV=2, G=2, L=64, Dh=16, seed=0):
    qh = jax.random.normal(jax.random.PRNGKey(seed), (B, KV, G, L, Dh))
    kh = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, KV, L, Dh))
    return qh, kh


def _dense_reference_plan(qh, kh, k_ratio, s, w, causal=True):
    """Same pipeline without chunking (threshold top-k for parity)."""
    B, KV, G, L, Dh = qh.shape
    pam = jnp.einsum("bkgqd,bkld->bkgql", qh, kh) * Dh ** -0.5
    if causal:
        tri = jnp.tril(jnp.ones((L, L), bool))
        pam = jnp.where(tri, pam, -1e30)
    k = topk_count(L, k_ratio)
    thr = jax.lax.top_k(pam, k)[0][..., -1:]
    mask = pam >= thr
    if causal:
        mask = mask & jnp.tril(jnp.ones((L, L), bool))
    spa = jnp.where(mask, pam, 0.0)
    sim = local_similarity(spa, w, s)
    return mask, sim


class TestChunkedPlan:
    def test_matches_unchunked_pipeline(self):
        """Row-block scanning must not change the plan (windows are
        self-contained -- the paper's locality argument): a single-block
        scan is the unchunked pipeline."""
        qh, kh = _heads(L=64)
        kw = dict(k_ratio=0.2, s_threshold=0.7, window=8, f_threshold=2)
        plan = chunked_plan_scan(qh, kh, row_block=16, **kw)
        ref = chunked_plan_scan(qh, kh, row_block=64, **kw)
        for got, want in zip(plan, ref):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_bisection_vs_exact_topk_overlap(self):
        """The 8-iteration bisection threshold selects (almost) the same
        entries as exact top-k: >=95% row-wise overlap."""
        qh, kh = _heads(L=64, seed=5)
        B, KV, G, L, Dh = qh.shape
        pam = jnp.einsum("bkgqd,bkld->bkgql", qh, kh) * Dh ** -0.5
        tri = jnp.tril(jnp.ones((L, L), bool))
        pam = jnp.where(tri, pam, -1e30)
        k = topk_count(L, 0.2)
        exact = pam >= jax.lax.top_k(pam, k)[0][..., -1:]
        hi = pam.max(-1, keepdims=True)
        lo = jnp.min(jnp.where(pam < -1e29, hi, pam), -1, keepdims=True)
        for _ in range(12):
            mid = 0.5 * (lo + hi)
            cnt = (pam >= mid).sum(-1, keepdims=True)
            lo = jnp.where(cnt >= k, mid, lo)
            hi = jnp.where(cnt >= k, hi, mid)
        approx = (pam >= lo) & tri
        exact = exact & tri  # early causal rows: exact top-k spills onto
        # the -1e30 fill (fewer valid entries than k); compare valid only
        inter = (exact & approx).sum()
        union = (exact | approx).sum()
        assert float(inter / union) > 0.95

    def test_row_block_invariance(self):
        qh, kh = _heads(L=64, seed=7)
        kw = dict(k_ratio=0.15, s_threshold=0.6, window=8, f_threshold=2)
        a = chunked_plan_scan(qh, kh, row_block=8, **kw)
        b = chunked_plan_scan(qh, kh, row_block=32, **kw)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_bisection_threshold_close_to_exact_k(self):
        qh, kh = _heads(L=128, seed=3)
        plan = chunked_plan_scan(qh, kh, k_ratio=0.1, s_threshold=0.5,
                                 window=8, f_threshold=2, row_block=32)
        # kv_keep derives from masks whose per-row count ~ k (+- ties/eps)
        # sanity: keep fraction bounded by a loose band around k/L
        frac = float(plan.kv_keep.mean())
        assert 0.05 <= frac <= 1.0

    def test_causal_leaders_not_future(self):
        qh, kh = _heads(L=64, seed=9)
        plan = chunked_plan_scan(qh, kh, k_ratio=0.2, s_threshold=0.9,
                                 window=8, f_threshold=2, row_block=16)
        lead = np.asarray(plan.q_leader)
        rows = np.broadcast_to(np.arange(64), lead.shape)
        assert (lead <= rows).all()

    def test_ffn_leaders_critical(self):
        qh, kh = _heads(L=64, seed=11)
        plan = chunked_plan_scan(qh, kh, k_ratio=0.2, s_threshold=0.9,
                                 window=8, f_threshold=2, row_block=16)
        crit = np.asarray(plan.ffn_critical)
        lead = np.asarray(plan.ffn_leader)
        assert np.take_along_axis(crit, lead, axis=-1).all()


class TestChunkedExecution:
    def _ref_exec(self, q, k, v, plan, scale):
        B, KV, G, L, Dh = q.shape
        kr = jnp.broadcast_to(k[:, :, None], (B, KV, G, L, Dh))
        vr = jnp.broadcast_to(v[:, :, None], (B, KV, G, L, Dh))
        qe = gather_rows(q, plan.q_leader)
        i = plan.q_leader[..., :, None]
        j = jnp.arange(L)
        m = plan.kv_keep[..., None, :] & (j <= i)
        s = jnp.einsum("bkgqd,bkgld->bkgql", qe, kr) * scale
        s = jnp.where(m, s, -1e30)
        a = jax.nn.softmax(s, axis=-1) * m.astype(s.dtype)
        a = a / jnp.maximum(a.sum(-1, keepdims=True), 1e-9)
        return jnp.einsum("bkgql,bkgld->bkgqd", a, vr)

    def test_full_capacity_matches_reference(self):
        qh, kh = _heads(L=64, seed=21)
        v = jax.random.normal(jax.random.PRNGKey(22), kh.shape)
        plan = chunked_plan_scan(qh, kh, k_ratio=0.2, s_threshold=0.7,
                                 window=8, f_threshold=2, row_block=16)
        out = spls_attention_chunked(qh, kh, v, plan, 64, 64,
                                     scale=16 ** -0.5, kv_chunk=16)
        ref = self._ref_exec(qh, kh, v, plan, 16 ** -0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)

    def test_kv_chunk_invariance(self):
        qh, kh = _heads(L=64, seed=31)
        v = jax.random.normal(jax.random.PRNGKey(32), kh.shape)
        plan = chunked_plan_scan(qh, kh, k_ratio=0.2, s_threshold=0.7,
                                 window=8, f_threshold=2, row_block=16)
        a = spls_attention_chunked(qh, kh, v, plan, 64, 64, kv_chunk=16)
        b = spls_attention_chunked(qh, kh, v, plan, 64, 64, kv_chunk=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    def test_similar_rows_copy_leaders(self):
        qh, kh = _heads(L=64, seed=41)
        v = jax.random.normal(jax.random.PRNGKey(42), kh.shape)
        plan = chunked_plan_scan(qh, kh, k_ratio=0.2, s_threshold=0.95,
                                 window=8, f_threshold=2, row_block=16)
        out = np.asarray(spls_attention_chunked(qh, kh, v, plan, 64, 64))
        lead = np.asarray(plan.q_leader)
        got = np.take_along_axis(out, lead[..., None], axis=-2)
        np.testing.assert_allclose(out, got, atol=1e-6)

    def test_model_integration_long_seq(self):
        """A model with SPLS at L >= threshold routes through the chunked
        path and stays finite."""
        import dataclasses
        from repro.configs.base import ArchConfig, BlockCfg
        from repro.core.spls import SPLSConfig
        from repro.models import forward, init_params
        import repro.models.blocks as blocks_mod
        cfg = ArchConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                         n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64,
                         period=(BlockCfg(),), remat=False,
                         spls=SPLSConfig(enabled=True, k_ratio=0.2,
                                         s_threshold=0.6, f_threshold=2,
                                         window=8,
                                         q_capacity_ratio=0.75,
                                         kv_capacity_ratio=0.75))
        old = blocks_mod._SPLS_CHUNK_THRESHOLD
        blocks_mod._SPLS_CHUNK_THRESHOLD = 64
        try:
            params = init_params(cfg, jax.random.PRNGKey(0))
            toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 64)
            logits = forward(cfg, params, toks)
            assert bool(jnp.isfinite(logits).all())
        finally:
            blocks_mod._SPLS_CHUNK_THRESHOLD = old
