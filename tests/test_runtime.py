"""Integration tests: checkpoint/restart, failure healing, elastic
re-meshing, straggler detection, gradient compression, data determinism,
the serving engine, and the optimizer."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings
from _propcheck import strategies as st

from repro.checkpoint import (cleanup_old, latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.configs.base import ArchConfig, BlockCfg
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.optim import (AdamWConfig, adamw_init, adamw_update, compress,
                         decompress, global_norm)
from repro.runtime import (FailureSimulator, Heartbeat, StragglerDetector,
                           Trainer, TrainerConfig, plan_elastic_mesh,
                           rescale_batch)
from repro.runtime.fault_tolerance import retry_with_backoff

jax.config.update("jax_platform_name", "cpu")


def _tiny_cfg(**kw):
    base = dict(name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=64,
                period=(BlockCfg(),), remat=False)
    base.update(kw)
    return ArchConfig(**base)


def _tiny_data(cfg):
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
                "b": (jnp.ones((2,)), {"c": jnp.zeros((5,), jnp.int32)})}
        save_checkpoint(str(tmp_path), 7, tree, data_step=7)
        like = jax.tree.map(jnp.zeros_like, tree)
        got, step, dstep = restore_checkpoint(str(tmp_path), like)
        assert step == 7 and dstep == 7
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), got, tree)

    def test_latest_and_cleanup(self, tmp_path):
        tree = {"x": jnp.ones(3)}
        for s in (10, 20, 30, 40):
            save_checkpoint(str(tmp_path), s, tree, keep=2)
        assert latest_step(str(tmp_path)) == 40
        steps = sorted(int(d.name[5:]) for d in tmp_path.iterdir()
                       if d.name.startswith("step_"))
        assert steps == [30, 40]

    def test_uncommitted_ignored(self, tmp_path):
        tree = {"x": jnp.ones(3)}
        save_checkpoint(str(tmp_path), 5, tree)
        # fake a partial write
        d = tmp_path / "step_000000099"
        (d / "arrays").mkdir(parents=True)
        (d / "MANIFEST.json").write_text("{}")
        assert latest_step(str(tmp_path)) == 5

    def test_restore_casts_dtype(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(3, jnp.float32)})
        got, _, _ = restore_checkpoint(str(tmp_path),
                                       {"x": jnp.zeros(3, jnp.bfloat16)})
        assert got["x"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# trainer: end-to-end + healing
# ---------------------------------------------------------------------------

class TestTrainer:
    def test_loss_decreases(self):
        cfg = _tiny_cfg()
        t = Trainer(cfg, TrainerConfig(total_steps=60, log_every=10),
                    _tiny_data(cfg))
        out = t.run()
        losses = [m["loss"] for m in out["metrics"]]
        assert out["final_step"] == 60
        assert losses[-1] < losses[0] - 0.3, losses

    def test_restart_resumes_from_checkpoint(self, tmp_path):
        cfg = _tiny_cfg()
        tc = TrainerConfig(total_steps=30, ckpt_dir=str(tmp_path),
                           ckpt_every=10, log_every=5)
        t1 = Trainer(cfg, tc, _tiny_data(cfg))
        t1.restore_or_init()
        while t1.step < 20:
            b = synthetic_batch(t1.data_cfg, t1.step)
            t1.params, t1.opt_state, _ = t1._train_step(
                t1.params, t1.opt_state, b)
            t1.step += 1
            if t1.step % 10 == 0:
                t1.save()
        # fresh trainer resumes at 20, not 0
        t2 = Trainer(cfg, tc, _tiny_data(cfg))
        t2.restore_or_init()
        assert t2.step == 20

    def test_heals_injected_failures(self, tmp_path):
        cfg = _tiny_cfg()
        sim = FailureSimulator(fail_at_steps=(12, 23))
        t = Trainer(cfg, TrainerConfig(total_steps=30,
                                       ckpt_dir=str(tmp_path),
                                       ckpt_every=5, log_every=10),
                    _tiny_data(cfg), failure_sim=sim)
        out = t.run()
        assert out["final_step"] == 30  # survived two failures

    def test_spls_trains(self):
        from repro.core.spls import SPLSConfig
        cfg = _tiny_cfg(spls=SPLSConfig(enabled=True, k_ratio=0.3,
                                        s_threshold=0.6, f_threshold=1,
                                        window=4))
        t = Trainer(cfg, TrainerConfig(total_steps=30, log_every=10),
                    _tiny_data(cfg))
        out = t.run()
        assert np.isfinite(out["metrics"][-1]["loss"])


# ---------------------------------------------------------------------------
# fault tolerance primitives
# ---------------------------------------------------------------------------

class TestFaultTolerance:
    def test_heartbeat(self):
        now = [0.0]
        hb = Heartbeat(timeout_s=10.0, clock=lambda: now[0])
        hb.ping("a")
        hb.ping("b")
        now[0] = 5.0
        hb.ping("a")
        now[0] = 12.0
        assert hb.dead_hosts() == ["b"]
        assert hb.alive_hosts() == ["a"]

    def test_straggler_detection(self):
        sd = StragglerDetector(threshold=2.0)
        for host in ("a", "b", "c"):
            for _ in range(8):
                sd.record(host, 1.0)
        sd.record("c", 5.0)
        assert sd.stragglers() == ["c"]

    def test_retry_with_backoff(self):
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise OSError("transient")
            return "ok"

        assert retry_with_backoff(flaky, max_retries=5,
                                  sleep=lambda s: None) == "ok"
        assert len(calls) == 3

    def test_retry_exhausts(self):
        with pytest.raises(OSError):
            retry_with_backoff(lambda: (_ for _ in ()).throw(OSError("x")),
                               max_retries=2, sleep=lambda s: None)


class TestElastic:
    def test_plan_survives_node_loss(self):
        plan = plan_elastic_mesh(alive=[f"h{i}" for i in range(60)],
                                 chips_per_host=4, model_parallel=16)
        assert plan.model == 16
        assert plan.data == 8  # 240 chips -> 15 data -> pow2 8

    def test_plan_raises_when_too_small(self):
        with pytest.raises(RuntimeError):
            plan_elastic_mesh(alive=["h0"], chips_per_host=4,
                              model_parallel=16)

    def test_rescale_policies(self):
        assert rescale_batch(256, 16, 8, "keep_global") == 256
        assert rescale_batch(256, 16, 8, "keep_per_shard") == 128

    def test_reshard_roundtrip_across_meshes(self):
        """A checkpoint written under one sharding restores onto another
        mesh -- the elastic-restart path."""
        import tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_cpu_mesh
        x = jnp.arange(64.0).reshape(8, 8)
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, {"x": x})
            mesh = make_cpu_mesh(1, 1)
            shd = {"x": NamedSharding(mesh, P("data", None))}
            got, _, _ = restore_checkpoint(d, {"x": jnp.zeros_like(x)},
                                           shardings=shd)
            np.testing.assert_array_equal(np.asarray(got["x"]), np.asarray(x))


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

class TestGradCompression:
    def test_roundtrip_error_bounded(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, scale, res = compress(g)
        deq = decompress(q, scale, g.shape)
        # block-quantized int8: error <= scale/2 per element
        err = np.abs(np.asarray(deq - g))
        assert err.max() <= float(scale.max()) * 0.51 + 1e-7

    def test_error_feedback_accumulates(self):
        """Residual re-injection: mean of dequantized grads over many steps
        converges to the true mean (error feedback kills the bias)."""
        g = jax.random.normal(jax.random.PRNGKey(1), (512,)) * 1e-3
        res = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        for _ in range(64):
            q, scale, res = compress(g, res)
            acc = acc + decompress(q, scale, g.shape)
        np.testing.assert_allclose(np.asarray(acc / 64), np.asarray(g),
                                   atol=2e-5)

    def test_compression_ratio(self):
        g = jax.random.normal(jax.random.PRNGKey(2), (4096,))
        q, scale, _ = compress(g)
        raw = g.size * 4
        packed = q.size * 1 + scale.size * 4
        assert packed < raw / 3.5  # ~4x minus per-block scales

    def test_int8_codes_in_range(self):
        g = jax.random.normal(jax.random.PRNGKey(3), (300,)) * 100
        q, _, _ = compress(g)
        assert q.dtype == jnp.int8
        assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_deterministic_restart(self):
        cfg = DataConfig(seed=3, seq_len=16, global_batch=2)
        a = synthetic_batch(cfg, 41)
        b = synthetic_batch(cfg, 41)
        np.testing.assert_array_equal(np.asarray(a["inputs"]),
                                      np.asarray(b["inputs"]))

    def test_steps_differ(self):
        cfg = DataConfig(seed=3, seq_len=16, global_batch=2)
        a = synthetic_batch(cfg, 1)
        b = synthetic_batch(cfg, 2)
        assert not np.array_equal(np.asarray(a["inputs"]),
                                  np.asarray(b["inputs"]))

    def test_lm_task_is_learnable_structure(self):
        cfg = DataConfig(seed=0, seq_len=256, global_batch=4, ngram=2)
        batch = synthetic_batch(cfg, 0)
        # tokens are in range and not constant
        toks = np.asarray(batch["inputs"])
        assert toks.min() >= 0 and toks.max() < cfg.vocab_size
        assert len(np.unique(toks)) > 10

    def test_embeddings_mode(self):
        cfg = DataConfig(seed=0, seq_len=16, global_batch=2,
                         input_mode="embeddings", d_model=32)
        b = synthetic_batch(cfg, 0)
        assert b["inputs"].shape == (2, 15, 32)
        assert b["labels"].shape == (2, 15)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

class TestAdamW:
    def test_converges_on_quadratic(self):
        p = {"w": jnp.asarray([5.0, -3.0])}
        cfg = AdamWConfig(weight_decay=0.0, clip_norm=None)
        st_ = adamw_init(cfg, p)
        for _ in range(300):
            g = jax.tree.map(lambda w: 2 * w, p)
            p, st_, _ = adamw_update(cfg, g, st_, p, jnp.asarray(0.05))
        assert float(jnp.abs(p["w"]).max()) < 0.05

    def test_weight_decay_shrinks(self):
        p = {"w": jnp.ones(4)}
        cfg = AdamWConfig(weight_decay=0.5, clip_norm=None)
        st_ = adamw_init(cfg, p)
        g = {"w": jnp.zeros(4)}
        p2, _, _ = adamw_update(cfg, g, st_, p, jnp.asarray(0.1))
        assert float(p2["w"][0]) < 1.0

    def test_clip_bounds_update(self):
        p = {"w": jnp.zeros(3)}
        cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
        st_ = adamw_init(cfg, p)
        g = {"w": jnp.full((3,), 1e6)}
        _, _, m = adamw_update(cfg, g, st_, p, jnp.asarray(0.1))
        assert float(m["grad_norm"]) > 1e5  # reported pre-clip

    @given(st.floats(1e-5, 1e-1), st.integers(0, 5))
    @settings(max_examples=10, deadline=None)
    def test_update_finite(self, lr, seed):
        p = {"w": jax.random.normal(jax.random.PRNGKey(seed), (8,))}
        cfg = AdamWConfig()
        st_ = adamw_init(cfg, p)
        g = {"w": jax.random.normal(jax.random.PRNGKey(seed + 1), (8,))}
        p2, _, _ = adamw_update(cfg, g, st_, p, jnp.asarray(lr))
        assert np.isfinite(np.asarray(p2["w"])).all()


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

class TestServing:
    def test_engine_matches_sequential_decode(self):
        from repro.models import forward, init_params
        from repro.runtime.serve import Request, ServeConfig, ServingEngine
        cfg = _tiny_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompt = jax.random.randint(jax.random.PRNGKey(1), (12,), 0,
                                    cfg.vocab_size)
        # reference: greedy via repeated dense forward
        seq = list(np.asarray(prompt))
        for _ in range(6):
            lg = forward(cfg, params, jnp.asarray(seq)[None, :])
            seq.append(int(jnp.argmax(lg[0, -1])))
        want = seq[12:]

        eng = ServingEngine(cfg, params, ServeConfig(n_slots=2, max_len=32))
        req = Request(rid=0, prompt=prompt, max_new_tokens=6)
        eng.submit(req)
        ticks = 0
        while not req.done and ticks < 50:
            eng.tick()
            ticks += 1
        assert req.output == want

    def test_continuous_batching_drains_queue(self):
        from repro.models import init_params
        from repro.runtime.serve import Request, ServeConfig, ServingEngine
        cfg = _tiny_cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, ServeConfig(n_slots=2, max_len=48))
        reqs = []
        for i in range(5):  # more requests than slots
            prompt = jax.random.randint(jax.random.PRNGKey(i), (8,), 0,
                                        cfg.vocab_size)
            r = Request(rid=i, prompt=prompt, max_new_tokens=4)
            reqs.append(r)
            eng.submit(r)
        ticks = 0
        while (eng.queue or any(s is not None for s in eng.slots)) \
                and ticks < 200:
            eng.tick()
            ticks += 1
        assert all(r.done for r in reqs)
        assert all(len(r.output) == 4 for r in reqs)
