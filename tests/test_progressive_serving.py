"""Progressive SPLS for serving: streaming per-chunk plan construction,
chunked+SPLS prefill parity with the full-prefill pruned engine, page-prune
vote accumulation, O(chunk * L) plan memory, the PagePool double-free guard,
the padded-chunk null-page sentinel, and backend-kind mismatch warnings."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.spls import SPLSConfig
from repro.core.spls_chunked import plan_chunk, votes_from_kv_any
from repro.core.topk import topk_count
from repro.models import init_params, resolve_backend
from repro.models import attn_backend as ab
from repro.serving import (PagePool, PagedServingEngine, Request,
                           Scheduler, SchedulerConfig, ServeConfig,
                           ServingEngine, SeqState, spls_token_votes)

jax.config.update("jax_platform_name", "cpu")

_PARAMS_CACHE = {}


def _cfg(**kw):
    base = dict(name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=64, period=(BlockCfg(),),
                remat=False)
    base.update(kw)
    return ArchConfig(**base)


def _spls_cfg(**kw):
    spls = dict(enabled=True, k_ratio=0.12, s_threshold=0.6, f_threshold=2,
                window=4, causal=True)
    spls.update(kw.pop("spls_kw", {}))
    return _cfg(name="tiny-spls-prog", spls=SPLSConfig(**spls), **kw)


def _params(cfg):
    key = (cfg.name, cfg.period, cfg.spls.enabled)
    if key not in _PARAMS_CACHE:
        _PARAMS_CACHE[key] = init_params(cfg, jax.random.PRNGKey(0))
    return _PARAMS_CACHE[key]


def _reqs(cfg, lens, max_new=5, seed0=0):
    return [Request(rid=i, prompt=jax.random.randint(
        jax.random.PRNGKey(seed0 + i), (lp,), 0, cfg.vocab_size),
        max_new_tokens=max_new) for i, lp in enumerate(lens)]


def _drain(engine, reqs):
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained(max_ticks=3000)
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


# ---------------------------------------------------------------------------
# core: streaming plan blocks
# ---------------------------------------------------------------------------

class TestPlanChunkStreaming:
    def _heads(self, B=1, KV=2, G=2, L=32, Dh=16, seed=0):
        qh = jax.random.normal(jax.random.PRNGKey(seed), (B, KV, G, L, Dh))
        kh = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, KV, L, Dh))
        return qh, kh

    def test_streaming_equals_single_block(self):
        """Chunk-by-chunk plan blocks over a padded, progressively filled
        column buffer reproduce the single-block plan exactly -- including
        the accumulated column votes.  This is the invariant that makes
        chunked and full prefills agree."""
        L, S, C, w = 32, 48, 8, 4
        qh, kh = self._heads(L=L)
        k = topk_count(L, 0.2)
        kw = dict(k=k, s_threshold=0.7, window=w, f_threshold=2, causal=True)

        ref = plan_chunk(qh, kh, row0=0, n_valid_rows=L, n_cols=L, **kw)

        # streaming: the column buffer is larger than the prompt and only
        # filled up to the current chunk's end; the rest is garbage
        noise = jax.random.normal(jax.random.PRNGKey(9),
                                  (1, 2, S - L, 16)) * 100
        acc = None
        got = {f: [] for f in ("mask", "q_critical", "q_leader",
                               "ffn_critical", "ffn_leader")}
        for c0 in range(0, L, C):
            seen = c0 + C
            kh_buf = jnp.concatenate(
                [kh[:, :, :seen], jnp.zeros((1, 2, S - seen, 16))], axis=2)
            kh_buf = kh_buf.at[:, :, L:].set(noise)  # garbage past prompt
            pb = plan_chunk(qh[..., c0:c0 + C, :], kh_buf, row0=c0,
                            n_valid_rows=C, n_cols=seen, **kw)
            acc = pb.kv_any if acc is None else acc | pb.kv_any
            got["mask"].append(pb.mask[..., :L])
            got["q_critical"].append(pb.q_critical)
            got["q_leader"].append(pb.q_leader)
            got["ffn_critical"].append(pb.ffn_critical)
            got["ffn_leader"].append(pb.ffn_leader)

        for f in got:
            want = np.asarray(getattr(ref, f))
            have = np.concatenate([np.asarray(a) for a in got[f]], axis=-2
                                  if f == "mask" else -1)
            np.testing.assert_array_equal(have, want, err_msg=f)
        np.testing.assert_array_equal(
            np.asarray(votes_from_kv_any(acc))[:L],
            np.asarray(votes_from_kv_any(ref.kv_any)))

    def test_one_jit_covers_all_lengths(self):
        """k / row0 / valid counts are traced: a single compiled plan_chunk
        serves every prompt length (no per-length recompilation)."""
        qh, kh = self._heads(L=32)
        fn = jax.jit(lambda q, khh, k, r0, nv, nc: plan_chunk(
            q, khh, k=k, row0=r0, n_valid_rows=nv, n_cols=nc,
            s_threshold=0.7, window=4, f_threshold=2, causal=True))
        a = fn(qh[..., :8, :], kh, 4, 0, 8, 32)
        b = fn(qh[..., 8:16, :], kh, 7, 8, 6, 30)  # different scalars
        assert a.mask.shape == b.mask.shape
        assert fn._cache_size() == 1

    def test_votes_no_quadratic_intermediate(self):
        """The rerouted spls_token_votes never materializes an O(L^2)
        intermediate at an 8k prompt (jaxpr shape audit)."""
        cfg = _spls_cfg(spls_kw=dict(window=8))
        params = _params(cfg)
        Lp = 8192
        prompt = jax.ShapeDtypeStruct((Lp,), jnp.int32)
        jaxpr = jax.make_jaxpr(
            lambda p, t: spls_token_votes(cfg, p, t))(params, prompt)
        biggest = _max_aval_size(jaxpr.jaxpr)
        assert biggest < Lp * Lp, biggest  # dense plan would be H * L^2

    def test_chunk_step_no_quadratic_intermediate(self):
        """The per-chunk SPLS prefill step stays O(chunk * S) at an
        8k-slot table (jaxpr shape audit of the whole layer scan)."""
        from repro.serving import (init_paged_cache, init_pos_pages,
                                   init_pred_cache, paged_prefill_chunk_spls)
        cfg = _spls_cfg(spls_kw=dict(window=8))
        params = _params(cfg)
        ps, CS = 16, 64
        P = 512                      # 8192 slots
        n_pages = P + 1
        cache = jax.eval_shape(
            lambda: init_paged_cache(cfg, n_pages, ps))
        pred = jax.eval_shape(lambda: init_pred_cache(cfg, n_pages, ps))
        S = P * ps
        jaxpr = jax.make_jaxpr(
            lambda p, c, pc, pp, tb, s0, t, v, k: paged_prefill_chunk_spls(
                cfg, p, c, pc, pp, tb, s0, t, v, k))(
            params, cache, pred,
            jax.ShapeDtypeStruct((n_pages, ps), jnp.int32),
            jax.ShapeDtypeStruct((P,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((1, CS), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
        biggest = _max_aval_size(jaxpr.jaxpr)
        # O(CS * S) blocks are fine (largest: the windowed-L1 pairwise
        # tensor, heads * CS * window * S); O(S^2) is not
        assert biggest <= 64 * CS * S, biggest
        assert biggest < S * S, biggest


def _max_aval_size(jaxpr) -> int:
    best = 0
    for j in _iter_jaxprs(jaxpr):
        for eqn in j.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                size = getattr(aval, "size", 0)
                best = max(best, int(size))
    return best


def _iter_jaxprs(j):
    yield j
    for eqn in j.eqns:
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for u in vs:
                if isinstance(u, jax.core.ClosedJaxpr):
                    yield from _iter_jaxprs(u.jaxpr)
                elif isinstance(u, jax.core.Jaxpr):
                    yield from _iter_jaxprs(u)


# ---------------------------------------------------------------------------
# engine: chunked+SPLS prefill parity and page savings
# ---------------------------------------------------------------------------

class TestChunkedSplsServing:
    def _run(self, cfg, params, prefill_chunk, lens, *, prune=True,
             max_new=5, n_slots=3, max_len=64, page_size=4,
             backend="xla_paged_decode", vote=0.5):
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=n_slots, max_len=max_len, page_size=page_size,
            prefill_chunk=prefill_chunk, attn_backend=backend,
            spls_page_prune=prune, spls_prune_vote=vote))
        outs = _drain(eng, _reqs(cfg, lens, max_new=max_new))
        return outs, eng

    @pytest.mark.parametrize("chunk", [8, 16])
    def test_parity_with_full_prefill_pruned(self, chunk):
        """Greedy outputs of chunked+SPLS prefill (pruning on) match the
        full-prefill pruned engine bit-for-bit in the no-preemption
        regime, for multiple chunkings."""
        cfg = _spls_cfg()
        params = _params(cfg)
        lens = [30, 18, 25, 41]
        full, _ = self._run(cfg, params, prefill_chunk=64, lens=lens)
        chunked, eng = self._run(cfg, params, prefill_chunk=chunk,
                                 lens=lens)
        assert eng.stats["prefill_chunks"] >= sum(-(-l // chunk)
                                                  for l in lens)
        assert eng.stats["preemptions"] == 0
        assert full == chunked

    def test_parity_both_paged_backends(self):
        cfg = _spls_cfg()
        params = _params(cfg)
        outs = {}
        for be in ("xla_paged_decode", "pallas_paged_decode"):
            outs[be], _ = self._run(cfg, params, prefill_chunk=8,
                                    lens=[22, 13], backend=be)
        assert outs["xla_paged_decode"] == outs["pallas_paged_decode"]

    def test_no_prune_matches_dense_engine(self):
        """Chunked SPLS prefill with pruning *off* still executes the
        sparse (simulation-mode) compute -- outputs must equal the dense
        fixed-slot engine's, which prefills whole prompts."""
        cfg = _spls_cfg()
        params = _params(cfg)
        dense = _drain(
            ServingEngine(cfg, params, ServeConfig(n_slots=2, max_len=64)),
            _reqs(cfg, [27, 14, 33], max_new=4))
        chunked, _ = self._run(cfg, params, prefill_chunk=8,
                               lens=[27, 14, 33], prune=False, n_slots=2,
                               max_new=4)
        assert dense == chunked

    def test_sliding_window_chunked_spls(self):
        """SWA + chunked + SPLS: window masks evaluate original ids after
        padding and compaction; parity with full prefill holds."""
        cfg = _spls_cfg(period=(BlockCfg(window=6),))
        cfg = dataclasses.replace(cfg, name="tiny-spls-swa")
        params = _params(cfg)
        lens = [29, 17]
        full, _ = self._run(cfg, params, prefill_chunk=64, lens=lens)
        chunked, _ = self._run(cfg, params, prefill_chunk=8, lens=lens)
        assert full == chunked

    def test_chunked_spls_prunes_pages(self):
        """Peak pages with chunked+SPLS pruning land strictly below dense
        chunked prefill on the same workload."""
        cfg = _spls_cfg()
        params = _params(cfg)
        lens = [48, 40, 44]
        _, pruned = self._run(cfg, params, prefill_chunk=8, lens=lens,
                              max_len=80, vote=1.0)
        _, dense = self._run(cfg, params, prefill_chunk=8, lens=lens,
                             max_len=80, prune=False)
        assert pruned.stats["peak_pages"] < dense.stats["peak_pages"], \
            (pruned.stats, dense.stats)
        assert pruned.pool.free_pages == pruned.pool.capacity  # all freed

    def test_chunk_must_align_with_window(self):
        cfg = _spls_cfg()
        with pytest.raises(ValueError, match="window"):
            PagedServingEngine(cfg, _params(cfg), ServeConfig(
                n_slots=1, max_len=32, page_size=4, prefill_chunk=6))

    def test_preempted_chunked_spls_completes(self):
        """Preemption mid-prefill resets the vote accumulator with the
        SeqState; everything still drains (pruned continuations may differ
        under pool pressure -- documented determinism caveat)."""
        cfg = _spls_cfg()
        params = _params(cfg)
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=3, max_len=48, page_size=4, n_pages=13,
            prefill_chunk=8, attn_backend="xla_paged_decode"))
        reqs = _reqs(cfg, [28, 28, 28], max_new=4)
        _drain(eng, reqs)
        assert eng.pool.free_pages == eng.pool.capacity


# ---------------------------------------------------------------------------
# scheduler: post-prune accounting + abort guard
# ---------------------------------------------------------------------------

class TestPruneAwareScheduling:
    def test_note_prune_ema_and_lifetime_estimate(self):
        pool = PagePool(20, 4)
        sched = Scheduler(SchedulerConfig(prefill_chunk=8), pool,
                          max_len=64, prune_aware=True)
        dense = sched.lifetime_pages(32, 16)      # no estimate yet
        assert dense == pool.pages_for(48)
        sched.note_prune(32, 8)                   # 25% kept
        est = sched.lifetime_pages(32, 16)
        # chunked prefill still peaks at the dense prompt; lifetime is
        # kept + budget
        assert est == max(pool.pages_for(32), pool.pages_for(8 + 16))
        assert est < dense
        sched.note_prune(32, 32)                  # ratio EMA moves up
        assert sched.prune_ratio == pytest.approx(0.625)

    def test_optimistic_submit_accepts_after_estimate(self):
        """A request dense accounting would reject is accepted once a
        prune estimate exists (post-prune footprint fits)."""
        pool = PagePool(12, 4)                    # 11 usable pages
        sched = Scheduler(SchedulerConfig(prefill_chunk=8), pool,
                          max_len=64, prune_aware=True)

        class R:
            rid = 0
        # dense: pages_for(40 + 16) = 14 > 11 -> reject
        with pytest.raises(ValueError):
            sched.submit(R(), list(range(40)), 16)
        sched.note_prune(40, 10)                  # 25% kept observed
        sched.submit(R(), list(range(40)), 16)    # now fits: 10 prefill,
        assert len(sched.waiting) == 1            # ~7 post-prune lifetime

    def test_solo_preemption_abort_guard(self):
        """A lone sequence that can never fit is aborted after
        max_solo_preemptions instead of relooping prefill forever."""
        pool = PagePool(4, 4)                     # 3 usable pages
        sched = Scheduler(SchedulerConfig(max_solo_preemptions=2), pool,
                          max_len=64, prune_aware=True)

        class R:
            rid, output, max_new_tokens = 7, [], 4
        req = R()
        for i in range(3):
            st = SeqState(req=req, base_prompt=[1], tokens=[1], budget=4,
                          slot=0, admit_seq=i)
            sched.slots[0] = st
            ok = sched.grow_to(st, 32)            # needs 8 > 3 pages
            assert not ok
        assert sched.stats["aborted"] == 1
        assert sched.aborted == [req]
        assert sched.stats["preemptions"] == 2
        # counter cleared on abort: a resubmitted rid starts fresh
        assert sched._solo_preempts == {}

    def test_solo_counter_resets_on_success(self):
        """A transient solo-preemption must not accumulate across separate
        pressure events once the sequence grows successfully."""
        pool = PagePool(6, 4)
        sched = Scheduler(SchedulerConfig(max_solo_preemptions=2), pool,
                          max_len=64, prune_aware=True)

        class R:
            rid, output, max_new_tokens = 3, [], 4
        st = SeqState(req=R(), base_prompt=[1], tokens=[1], budget=4,
                      slot=0, admit_seq=0)
        sched.slots[0] = st
        assert not sched.grow_to(st, 64)          # too big: solo-preempt
        assert sched._solo_preempts == {3: 1}
        sched.slots[0] = st
        assert sched.grow_to(st, 8)               # fits: counter resets
        assert sched._solo_preempts == {}


# ---------------------------------------------------------------------------
# PagePool double-free guard
# ---------------------------------------------------------------------------

class TestPagePoolGuard:
    def test_double_free_raises(self):
        pool = PagePool(6, 4)
        a = pool.alloc(2)
        pool.free(a)
        with pytest.raises(ValueError, match="double-free|not currently"):
            pool.free(a)
        assert pool.free_pages == 5               # no duplicate ids

    def test_foreign_and_null_page_free_raises(self):
        pool = PagePool(6, 4)
        with pytest.raises(ValueError):
            pool.free([99])
        with pytest.raises(ValueError, match="null"):
            pool.free([0])

    def test_free_list_never_duplicates(self):
        pool = PagePool(5, 4)
        a = pool.alloc(4)
        pool.free(a)
        try:
            pool.free(a[:1])
        except ValueError:
            pass
        got = pool.alloc(4)
        assert sorted(got) == sorted(a)           # each page exactly once


# ---------------------------------------------------------------------------
# padded chunk: null page stays inert
# ---------------------------------------------------------------------------

class TestPaddedChunkSentinel:
    def test_null_page_pos_sentinel_after_padded_chunk(self):
        from repro.serving import (NULL_PAGE, POS_SENTINEL,
                                   init_paged_cache, init_pos_pages,
                                   paged_prefill_chunk)
        cfg = _cfg()
        params = _params(cfg)
        ps, P = 4, 4
        cache = init_paged_cache(cfg, 6, ps)
        pos_pages = init_pos_pages(6, ps)
        table = jnp.asarray([1, 2, NULL_PAGE, NULL_PAGE], jnp.int32)
        toks = jnp.zeros((1, 8), jnp.int32)       # 8-row chunk, 5 valid
        _, cache, pos_pages = paged_prefill_chunk(
            cfg, params, cache, pos_pages, table,
            jnp.asarray(0, jnp.int32), toks, jnp.asarray(5, jnp.int32))
        # padded rows 5..7 all scatter to null-page slot 0: it must hold
        # the sentinel, not a real position id
        np.testing.assert_array_equal(np.asarray(pos_pages[NULL_PAGE]),
                                      np.full((ps,), POS_SENTINEL))

    def test_window_decode_ignores_null_page_after_padded_chunks(self):
        """Engine-level: sliding-window attention through ragged chunked
        prefill (every chunk but the first is padded) matches the dense
        engine -- null-page slots never win window mass."""
        cfg = _cfg(name="tiny-swa2", period=(BlockCfg(window=5),))
        params = _params(cfg)
        lens = [21, 9]                            # 21 -> chunks 8, 8, 5
        dense = _drain(
            ServingEngine(cfg, params, ServeConfig(n_slots=2, max_len=40)),
            _reqs(cfg, lens))
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=40, page_size=4, prefill_chunk=8,
            attn_backend="xla_paged_decode"))
        paged = _drain(eng, _reqs(cfg, lens))
        assert dense == paged


# ---------------------------------------------------------------------------
# resolve_backend kind-mismatch diagnostics
# ---------------------------------------------------------------------------

class TestBackendKindMismatch:
    def setup_method(self):
        ab._warned_kind_mismatch.clear()

    def test_warns_and_falls_back(self):
        cfg = _cfg()
        with pytest.warns(RuntimeWarning, match=r"'xla_paged_decode'.*"
                          r"paged decode backend.*forward site"):
            name = resolve_backend("xla_paged_decode", cfg, L=64,
                                   platform="cpu")
        assert name == "xla_dense"                # the forward auto choice

    def test_warns_once_per_name_site(self):
        cfg = _cfg()
        with pytest.warns(RuntimeWarning):
            resolve_backend("xla_dense", cfg, L=64, decode=True,
                            platform="cpu")
        import warnings as w
        with w.catch_warnings():
            w.simplefilter("error")               # second call must be quiet
            got = resolve_backend("xla_dense", cfg, L=64, decode=True,
                                  platform="cpu")
        assert got == "xla_dense_decode"

    def test_strict_raises(self):
        cfg = _cfg()
        with pytest.raises(ValueError, match="forward site"):
            resolve_backend("xla_paged_decode", cfg, L=64, platform="cpu",
                            strict=True)
        ab.STRICT_BACKEND_KIND = True
        try:
            with pytest.raises(ValueError):
                resolve_backend("pallas_flash", cfg, L=64, decode=True,
                                platform="cpu")
        finally:
            ab.STRICT_BACKEND_KIND = False

    def test_engine_config_does_not_warn(self):
        """ServeConfig.attn_backend naming a paged decode backend is the
        paged engine's documented usage: the engine routes the name to its
        decode site and the prefill forward site resolves auto silently --
        no kind-mismatch warning, and STRICT_BACKEND_KIND stays usable."""
        import warnings as w
        cfg = _spls_cfg()
        params = _params(cfg)
        ab.STRICT_BACKEND_KIND = True
        try:
            with w.catch_warnings():
                w.simplefilter("error", RuntimeWarning)
                eng = PagedServingEngine(cfg, params, ServeConfig(
                    n_slots=1, max_len=48, page_size=4, prefill_chunk=8,
                    attn_backend="xla_paged_decode"))
                _drain(eng, _reqs(cfg, [12], max_new=2))
        finally:
            ab.STRICT_BACKEND_KIND = False

    def test_matching_kind_never_warns(self):
        import warnings as w
        cfg = _cfg()
        with w.catch_warnings():
            w.simplefilter("error")
            assert resolve_backend("xla_dense", cfg, L=64,
                                   platform="cpu") == "xla_dense"
            assert resolve_backend("xla_paged_decode", cfg, L=64,
                                   decode=True, paged=True,
                                   platform="cpu") == "xla_paged_decode"
