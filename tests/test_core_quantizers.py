"""Unit + property tests for the SPLS quantizers (repro.core.quantizers)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings
from _propcheck import strategies as st

from repro.core.quantizers import (apot_levels, apot_project,
                                   hlog_bitlevel_decode, hlog_bitlevel_encode,
                                   hlog_bitlevel_project, hlog_levels,
                                   hlog_project, pot_levels, pot_project,
                                   project_to_levels, quantize_dequantize,
                                   symmetric_quantize)

jax.config.update("jax_platform_name", "cpu")


class TestLevels:
    def test_hlog_levels_eq1(self):
        # eq (1): {2^0, 2^1, 2^0+2^1, 2^2, ..., 2^{n-2}, 2^{n-3}+2^{n-2}, 2^{n-1}}
        np.testing.assert_array_equal(
            hlog_levels(8),
            [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128])

    def test_hlog_levels_are_pot_union_midpoints(self):
        lv = set(hlog_levels(8).tolist())
        pot = {2.0 ** m for m in range(8)}
        mids = {1.5 * 2.0 ** m for m in range(1, 7)}
        assert lv == pot | mids

    def test_pot_levels(self):
        np.testing.assert_array_equal(pot_levels(4), [1, 2, 4, 8])

    def test_apot_denser_than_hlog(self):
        assert len(apot_levels(8)) > len(hlog_levels(8))
        # APoT contains every HLog level except pure singles already in it
        assert set(hlog_levels(8)) <= set(apot_levels(8)) | {1.0}


class TestProjection:
    def test_zero_maps_to_zero(self):
        for proj in (hlog_project, pot_project, apot_project):
            assert float(proj(jnp.zeros(3))[0]) == 0.0

    def test_tie_projects_up(self):
        # 40 is equidistant from 32 and 48 -> paper: project to higher level
        assert float(project_to_levels(jnp.asarray([40.0]), hlog_levels(8))[0]) == 48.0
        # 1.25*2^m boundary: 10 is equidistant from 8 and 12
        assert float(project_to_levels(jnp.asarray([10.0]), hlog_levels(8))[0]) == 12.0

    def test_sign_preserved(self):
        v = jnp.asarray([-42.0, 42.0])
        out = hlog_project(v)
        assert float(out[0]) == -float(out[1])

    def test_levels_are_fixed_points(self):
        lv = jnp.asarray(hlog_levels(8), jnp.float32)
        np.testing.assert_array_equal(hlog_project(lv), lv)

    @given(st.integers(min_value=-127, max_value=127))
    @settings(max_examples=64, deadline=None)
    def test_hlog_relative_error_bound(self, v):
        # HLog grid spacing is <= 1/3 of the magnitude -> rel error <= 1/5
        if v == 0:
            return
        out = float(hlog_project(jnp.asarray([float(v)]))[0])
        assert abs(out - v) / abs(v) <= 0.2 + 1e-6


class TestBitLevel:
    def test_bitlevel_matches_projection_exhaustive(self):
        """The SD unit (Fig. 12) is bit-exact vs. nearest-level projection."""
        v = jnp.arange(-127, 128).astype(jnp.float32)
        np.testing.assert_array_equal(hlog_bitlevel_project(v), hlog_project(v))

    def test_paper_example_fig12(self):
        # (00101010)_2 = 42 -> code (exp=5, form=1) -> 1.5 * 32 = 48
        code = hlog_bitlevel_encode(jnp.asarray([42]))
        assert int((code[0] >> 1) & 7) == 5 and int(code[0] & 1) == 1
        assert float(hlog_bitlevel_decode(code)[0]) == 48.0
        # (11101110)_2 = -18 two's complement -> paper codes (4, 0) -> -16
        code = hlog_bitlevel_encode(jnp.asarray([-18]))
        assert int((code[0] >> 1) & 7) == 4 and int(code[0] & 1) == 0
        assert float(hlog_bitlevel_decode(code)[0]) == -16.0

    def test_zero_roundtrip(self):
        assert float(hlog_bitlevel_project(jnp.asarray([0.0]))[0]) == 0.0

    def test_code_width_is_5_bits_plus_zero_flag(self):
        v = jnp.arange(-127, 128).astype(jnp.float32)
        codes = hlog_bitlevel_encode(v)
        nz = codes[v != 0]
        assert int(jnp.max(nz)) < (1 << 5)


class TestQuantizeDequantize:
    @pytest.mark.parametrize("method", ["hlog", "hlog_bitlevel", "pot", "apot", "none"])
    def test_scale_invariance(self, method):
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
        a = quantize_dequantize(x, method)
        b = quantize_dequantize(x * 7.5, method)
        np.testing.assert_allclose(np.asarray(b), np.asarray(a) * 7.5, rtol=1e-5)

    def test_error_ordering_hlog_between_pot_and_apot(self):
        """Fig. 7: PoT worst, APoT best, HLog close to APoT."""
        x = jax.random.normal(jax.random.PRNGKey(1), (4096,))
        err = {m: float(jnp.mean(jnp.abs(quantize_dequantize(x, m) - x)))
               for m in ("pot", "hlog", "apot")}
        assert err["apot"] <= err["hlog"] <= err["pot"]

    def test_symmetric_quantize_integer_grid(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (128,))
        q, scale = symmetric_quantize(x)
        np.testing.assert_allclose(np.asarray(q), np.round(np.asarray(q)))
        assert float(jnp.max(jnp.abs(q))) <= 127

    @given(st.lists(st.floats(min_value=-100, max_value=100,
                              allow_nan=False), min_size=1, max_size=64))
    @settings(max_examples=32, deadline=None)
    def test_hlog_idempotent(self, xs):
        """Projecting an already-projected tensor is a no-op (same scale)."""
        x = jnp.asarray(xs, jnp.float32)
        q, scale = symmetric_quantize(x)
        once = hlog_project(q)
        twice = hlog_project(once)
        np.testing.assert_allclose(np.asarray(twice), np.asarray(once))
