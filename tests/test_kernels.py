"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracles in repro.kernels.ref (assignment requirement c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings
from _propcheck import strategies as st

from repro.core.quantizers import hlog_project, symmetric_quantize
from repro.kernels import (flash_attention, hlog_qmatmul,
                           local_similarity_dist)
from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")


def _randn(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)


def _randint8(shape, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * 35
    return jnp.round(x).clip(-127, 127)


class TestHlogQMatmul:
    @pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 128, 384),
                                       (128, 256, 128), (512, 512, 256)])
    def test_shapes_exact(self, M, K, N):
        xq, wq = _randint8((M, K), 1), _randint8((K, N), 2)
        out = hlog_qmatmul(xq, wq, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.hlog_qmatmul_ref(xq, wq)),
                                   rtol=1e-6)

    @pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (256, 128, 256)])
    def test_block_shapes(self, bm, bn, bk):
        xq, wq = _randint8((256, 256), 3), _randint8((256, 256), 4)
        out = hlog_qmatmul(xq, wq, bm=bm, bn=bn, bk=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.hlog_qmatmul_ref(xq, wq)),
                                   rtol=1e-6)

    def test_inkernel_projection_matches_bitlevel(self):
        """In-kernel float projection == SD-unit projection on the int8 grid."""
        from repro.kernels.hlog_qmatmul import _hlog_project_inkernel
        v = jnp.arange(-127, 128).astype(jnp.float32)
        np.testing.assert_array_equal(np.asarray(_hlog_project_inkernel(v)),
                                      np.asarray(hlog_project(v)))

    def test_full_prediction_path(self):
        """Kernel applied to real activations after int8 pre-quantization."""
        x = _randn((128, 128), 5)
        w = _randn((128, 128), 6) * 0.1
        xq, sx = symmetric_quantize(x)
        wq, sw = symmetric_quantize(w)
        out = hlog_qmatmul(xq, wq, interpret=True) * sx * sw
        want = (hlog_project(xq) * sx) @ (hlog_project(wq) * sw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("L,Dh", [(128, 64), (256, 64), (256, 128),
                                      (384, 64)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_shapes(self, L, Dh, causal):
        q, k, v = (_randn((2, 2, L, Dh), s) for s in (1, 2, 3))
        out = flash_attention(q, k, v, causal=causal, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q, k, v = (_randn((1, 2, 256, 64), s, dtype) for s in (4, 5, 6))
        out = flash_attention(q, k, v, interpret=True)
        want = ref.flash_attention_ref(q, k, v)
        atol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), atol=atol)

    @pytest.mark.parametrize("window", [64, 128, 1024])
    def test_sliding_window(self, window):
        q, k, v = (_randn((1, 2, 512, 64), s) for s in (7, 8, 9))
        out = flash_attention(q, k, v, window=window, interpret=True)
        want = ref.flash_attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)

    def test_softcap(self):
        q, k, v = (_randn((1, 2, 256, 64), s) for s in (10, 11, 12))
        out = flash_attention(q, k, v, softcap=50.0, interpret=True)
        want = ref.flash_attention_ref(q, k, v, softcap=50.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)

    @pytest.mark.parametrize("keep_rate", [0.3, 0.7, 1.0])
    def test_spls_kv_keep_mask(self, keep_rate):
        """The paper's column-pruning mask (zero SPA columns)."""
        q, k, v = (_randn((2, 2, 256, 64), s) for s in (13, 14, 15))
        keep = jax.random.bernoulli(jax.random.PRNGKey(16), keep_rate,
                                    (2, 2, 256))
        keep = keep.at[:, :, 0].set(True)  # row 0 must see something
        out = flash_attention(q, k, v, kv_keep=keep, interpret=True)
        want = ref.flash_attention_ref(q, k, v, kv_keep=keep)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)

    def test_block_shape_sweep(self):
        q, k, v = (_randn((1, 1, 512, 64), s) for s in (17, 18, 19))
        want = ref.flash_attention_ref(q, k, v)
        for bq, bk in [(128, 128), (256, 128), (128, 256), (512, 512)]:
            out = flash_attention(q, k, v, block_q=bq, block_k=bk,
                                  interpret=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                       atol=2e-5, err_msg=f"bq={bq} bk={bk}")

    def test_fully_masked_rows_zero(self):
        """If SPLS kills every column a row could see, output must be 0."""
        q, k, v = (_randn((1, 1, 128, 64), s) for s in (20, 21, 22))
        keep = jnp.zeros((1, 1, 128), bool)
        out = flash_attention(q, k, v, causal=False, kv_keep=keep,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


class TestFlashAttentionBoundaries:
    """Exhaustive small-shape audit of the block-skip `live` predicates:
    window edges, causal block boundaries, ragged L (padding path), packed
    q_pos rows, and all-pruned kv_keep blocks -- every case vs the dense
    oracle."""

    @pytest.mark.parametrize("L", [16, 24, 40])
    @pytest.mark.parametrize("window", [1, 4, 8, 13, None])
    @pytest.mark.parametrize("causal", [True, False])
    def test_window_and_causal_edges(self, L, window, causal):
        q, k, v = (_randn((1, 2, L, 8), s) for s in (30, 31, 32))
        out = flash_attention(q, k, v, causal=causal, window=window,
                              block_q=8, block_k=8, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5,
                                   err_msg=f"L={L} w={window} c={causal}")

    @pytest.mark.parametrize("bq,bk", [(8, 8), (16, 8), (8, 16), (16, 16)])
    def test_ragged_padding(self, bq, bk):
        """L % block != 0 pads internally; padded K dies via keep mask."""
        L = 36
        q, k, v = (_randn((1, 1, L, 8), s) for s in (33, 34, 35))
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)

    @pytest.mark.parametrize("dead", [(0, 8), (8, 16), (24, 32), (8, 32)])
    def test_dead_kv_blocks_skipped_exactly(self, dead):
        """Whole-block kv_keep kills: skipped blocks must not perturb the
        running softmax state of surviving ones."""
        L = 32
        q, k, v = (_randn((2, 2, L, 8), s) for s in (36, 37, 38))
        keep = jnp.ones((2, 2, L), bool).at[:, :, dead[0]:dead[1]].set(False)
        keep = keep.at[:, :, 0].set(True)
        out = flash_attention(q, k, v, causal=True, kv_keep=keep,
                              block_q=8, block_k=8, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, kv_keep=keep)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)

    def test_window_one_touches_only_diagonal(self):
        """window=1 + causal: each row sees exactly itself (block edge)."""
        L = 24
        q, k, v = (_randn((1, 1, L, 8), s) for s in (39, 40, 41))
        out = flash_attention(q, k, v, causal=True, window=1,
                              block_q=8, block_k=8, interpret=True)
        np.testing.assert_allclose(np.asarray(out[0, 0]),
                                   np.asarray(v[0, 0]), atol=2e-5)

    def test_q_pos_packed_rows(self):
        """Shuffled q rows with original ids == oracle rows re-shuffled."""
        L = 32
        q, k, v = (_randn((1, 2, L, 8), s) for s in (42, 43, 44))
        perm = jax.random.permutation(jax.random.PRNGKey(45), L)
        q_pos = jnp.broadcast_to(perm.astype(jnp.int32), (1, 2, L))
        out = flash_attention(q[:, :, perm], k, v, causal=True, window=9,
                              q_pos=q_pos, block_q=8, block_k=8,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=9)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(want)[:, :, perm], atol=2e-5)

    def test_q_pos_with_ragged_padding_and_keep(self):
        L = 20  # pads to 24 with bq=8
        q, k, v = (_randn((1, 1, L, 8), s) for s in (46, 47, 48))
        perm = jax.random.permutation(jax.random.PRNGKey(49), L)
        keep = jax.random.bernoulli(jax.random.PRNGKey(50), 0.6, (1, 1, L))
        keep = keep.at[:, :, 0].set(True)
        out = flash_attention(q[:, :, perm], k, v, causal=True,
                              kv_keep=keep,
                              q_pos=jnp.broadcast_to(perm.astype(jnp.int32),
                                                     (1, 1, L)),
                              block_q=8, block_k=8, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, kv_keep=keep)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(want)[:, :, perm], atol=2e-5)

    def test_gqa_grouped_kv_equals_repeated(self):
        """Grouped (B, KV, L, Dh) k/v read via the index map == the same
        call with k/v explicitly repeated to H heads."""
        B, KV, G, L, Dh = 2, 2, 3, 32, 8
        H = KV * G
        q = _randn((B, H, L, Dh), 54)
        k = _randn((B, KV, L, Dh), 55)
        v = _randn((B, KV, L, Dh), 56)
        grouped = flash_attention(q, k, v, causal=True, window=9,
                                  block_q=8, block_k=8, interpret=True)
        kr = jnp.repeat(k, G, axis=1)
        vr = jnp.repeat(v, G, axis=1)
        repeated = flash_attention(q, kr, vr, causal=True, window=9,
                                   block_q=8, block_k=8, interpret=True)
        np.testing.assert_allclose(np.asarray(grouped),
                                   np.asarray(repeated), atol=1e-6)
        want = ref.flash_attention_ref(q, kr, vr, causal=True, window=9)
        np.testing.assert_allclose(np.asarray(grouped), np.asarray(want),
                                   atol=2e-5)

    def test_all_pruned_ragged(self):
        """Every column dead + ragged L: zero output, nothing NaN."""
        q, k, v = (_randn((1, 1, 20, 8), s) for s in (51, 52, 53))
        keep = jnp.zeros((1, 1, 20), bool)
        out = flash_attention(q, k, v, causal=False, kv_keep=keep,
                              block_q=8, block_k=8, interpret=True)
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


class TestLocalSimilarityKernel:
    @pytest.mark.parametrize("L,Lk,w", [(64, 128, 8), (64, 256, 8),
                                        (128, 128, 4), (96, 384, 8)])
    def test_shapes(self, L, Lk, w):
        spa = _randn((2, 2, L, Lk), 23)
        out = local_similarity_dist(spa, w=w, bk=128, interpret=True)
        want = ref.local_similarity_ref(spa, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_chunked_equals_unchunked(self):
        spa = _randn((1, 2, 64, 512), 24)
        a = local_similarity_dist(spa, w=8, bk=512, interpret=True)
        b = local_similarity_dist(spa, w=8, bk=128, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    @given(st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_property_symmetry_and_diag(self, seed):
        spa = _randn((1, 1, 16, 128), seed)
        d = np.asarray(local_similarity_dist(spa, w=8, bk=128,
                                             interpret=True))
        np.testing.assert_allclose(d, np.swapaxes(d, -1, -2), rtol=1e-5,
                                   atol=1e-4)
        assert np.abs(np.diagonal(d, axis1=-2, axis2=-1)).max() < 1e-4


class TestOpsFallback:
    def test_untileable_shapes_fall_back(self):
        q, k, v = (_randn((1, 1, 100, 64), s) for s in (25, 26, 27))
        out = ops.attention(q, k, v)  # 100 % 128 != 0 -> ref path
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=1e-6)

    def test_predict_matmul_untileable(self):
        xq, wq = _randint8((100, 64), 28), _randint8((64, 100), 29)
        np.testing.assert_allclose(
            np.asarray(ops.predict_matmul(xq, wq)),
            np.asarray(ref.hlog_qmatmul_ref(xq, wq)), rtol=1e-6)
