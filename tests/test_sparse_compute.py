"""End-to-end sparse compute: the gathered-matmul kernel vs the XLA
pack/unpack oracle, plan->compaction adapters (incl. the capacity-overflow
window-leader fallback), packed Q/MLP parity with the dense projections,
the capacity controller, the compute-backend registry, and engine-level
bit-for-bit parity of packed serving prefill with the dense-compute
(simulation-mode) baseline at capacity == L."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.sparse_exec import (compact_rows, gather_rows, spls_ffn,
                                    spls_ffn_packed)
from repro.core.spls import SparsityPlan, SPLSConfig
from repro.kernels.gathered_matmul import gather_rows_kernel, gathered_matmul
from repro.kernels.ref import gathered_matmul_ref
from repro.models import init_params
from repro.serving import PagedServingEngine, Request, ServeConfig
from repro.sparse_compute import (CapacityController, chunk_flops,
                                  available_compute_backends,
                                  packed_mlp, packed_project_q,
                                  resolve_compute_backend)

jax.config.update("jax_platform_name", "cpu")

_PARAMS_CACHE = {}


def _cfg(**kw):
    base = dict(name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=64, period=(BlockCfg(),),
                remat=False)
    base.update(kw)
    return ArchConfig(**base)


def _spls_cfg(**kw):
    spls = dict(enabled=True, k_ratio=0.12, s_threshold=0.6, f_threshold=2,
                window=4, causal=True)
    spls.update(kw.pop("spls_kw", {}))
    return _cfg(name="tiny-spls-sc", spls=SPLSConfig(**spls), **kw)


def _params(cfg):
    key = (cfg.name, cfg.n_kv_heads, cfg.spls.enabled, cfg.qk_norm)
    if key not in _PARAMS_CACHE:
        _PARAMS_CACHE[key] = init_params(cfg, jax.random.PRNGKey(0))
    return _PARAMS_CACHE[key]


# ---------------------------------------------------------------------------
# kernel parity vs the XLA pack/unpack oracle
# ---------------------------------------------------------------------------

class TestGatheredMatmulKernel:
    @pytest.mark.parametrize("L,D,F,C", [
        (33, 48, 40, 5),      # ragged everything
        (64, 64, 48, 16),     # capacity bucket < L
        (16, 32, 8, 16),      # capacity == L
        (40, 16, 128, 64),    # C > L (repeated rows / filler slots)
    ])
    def test_matches_oracle_bitwise(self, L, D, F, C):
        x = jax.random.normal(jax.random.PRNGKey(0), (L, D), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (D, F), jnp.float32)
        perm = jax.random.randint(jax.random.PRNGKey(2), (C,), 0, L)
        out = gathered_matmul(x, w, perm, bm=8, bn=16)
        ref = gathered_matmul_ref(x, w, perm)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_fused_scatter_matches_oracle(self):
        L, D, F, C, M = 32, 48, 24, 12, 50
        x = jax.random.normal(jax.random.PRNGKey(3), (L, D), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(4), (D, F), jnp.float32)
        perm = jax.random.randint(jax.random.PRNGKey(5), (C,), 0, L)
        slot = jax.random.randint(jax.random.PRNGKey(6), (M,), 0, C)
        out = gathered_matmul(x, w, perm, src_slot=slot, bm=4, bn=8)
        ref = gathered_matmul_ref(x, w, perm, src_slot=slot)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_blocked_contraction_close(self):
        """bk < D trades the bitwise guarantee for VMEM (documented);
        results stay allclose."""
        x = jax.random.normal(jax.random.PRNGKey(7), (32, 64), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(8), (64, 32), jnp.float32)
        perm = jnp.arange(10, dtype=jnp.int32)
        out = gathered_matmul(x, w, perm, bm=4, bn=16, bk=16)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(gathered_matmul_ref(x, w, perm)),
                                   atol=2e-5)

    def test_gather_rows_kernel(self):
        src = jax.random.normal(jax.random.PRNGKey(9), (12, 40))
        idx = jax.random.randint(jax.random.PRNGKey(10), (30,), 0, 12)
        np.testing.assert_array_equal(
            np.asarray(gather_rows_kernel(src, idx)), np.asarray(src[idx]))


# ---------------------------------------------------------------------------
# plan -> compaction adapter (incl. the overflow window-leader fallback)
# ---------------------------------------------------------------------------

class TestCompactRows:
    def test_full_capacity_identity(self):
        crit = jnp.asarray([[1, 0, 1, 0, 0, 1, 0, 0]], bool)
        lead = jnp.asarray([[0, 0, 2, 2, 2, 5, 5, 5]], jnp.int32)
        c = compact_rows(crit, 8, leader=lead, window=4)
        # every row reads its leader's slot; leaders read their own
        perm = np.asarray(c.perm)[0]
        slot = np.asarray(c.src_slot)[0]
        for r in range(8):
            assert perm[slot[r]] == int(lead[0, r])
        assert int(c.n_critical[0]) == 3

    def test_overflow_falls_back_to_window_leader(self):
        """Rows whose leader overflowed capacity read the first *packed*
        critical row of their window -- not the legacy last-slot clamp."""
        # window 4: rows 0..3 critical 0, 2; rows 4..7 critical 4, 5, 6
        crit = jnp.asarray([[1, 0, 1, 0, 1, 1, 1, 0]], bool)
        lead = jnp.asarray([[0, 0, 2, 2, 4, 5, 6, 6]], jnp.int32)
        # capacity 3 packs critical rows 0, 2, 4; rows 5, 6 overflow
        c = compact_rows(crit, 3, leader=lead, window=4)
        perm = np.asarray(c.perm)[0]
        slot = np.asarray(c.src_slot)[0]
        assert list(perm) == [0, 2, 4]
        assert perm[slot[5]] == 4        # window leader of rows 4..7
        assert perm[slot[6]] == 4
        assert perm[slot[7]] == 4        # follower of overflow leader 6
        # non-overflow rows untouched
        assert perm[slot[0]] == 0 and perm[slot[2]] == 2
        assert perm[slot[3]] == 2 and perm[slot[4]] == 4

    def test_overflowed_window_leader_clamps(self):
        """If even the window leader overflowed, the legacy clamp (last
        packed slot) is the final fallback."""
        crit = jnp.asarray([[1, 1, 0, 0, 1, 1, 0, 0]], bool)
        lead = jnp.asarray([[0, 1, 1, 0, 4, 5, 5, 4]], jnp.int32)
        c = compact_rows(crit, 2, leader=lead, window=4)   # packs 0, 1
        perm = np.asarray(c.perm)[0]
        slot = np.asarray(c.src_slot)[0]
        # window [4..7]'s leader (row 4) overflowed -> clamp to slot C-1
        for r in (4, 5, 6, 7):
            assert slot[r] == 1

    def test_extra_head_dims_broadcast(self):
        """Per-head leaders over a shared (cross-head union) pack."""
        crit = jnp.asarray([[1, 1, 0, 1]], bool)              # (1, 4)
        lead = jnp.asarray([[[[0, 0, 1, 3]], [[1, 1, 0, 3]]]],
                           jnp.int32)                          # (1, 2, 1, 4)
        c = compact_rows(crit, 4, leader=lead, window=4)
        perm = np.asarray(c.perm)[0]
        slot = np.asarray(c.src_slot)[0]
        assert perm[slot[0, 0, 2]] == 1 and perm[slot[1, 0, 2]] == 0


class TestSplsFfnPackedOverflow:
    """Satellite: spls_ffn_packed vs spls_ffn below capacity -- overflow
    rows must fall back to their window leader's output exactly."""

    def _plan(self, crit, lead, L):
        B = crit.shape[0]
        z = jnp.zeros((B, 1, L), bool)
        return SparsityPlan(
            attn_mask=jnp.zeros((B, 1, L, L), bool), q_critical=z,
            q_leader=jnp.zeros((B, 1, L), jnp.int32),
            kv_keep=z, ffn_critical=crit, ffn_leader=lead)

    def test_overflow_rows_read_window_leader_exactly(self):
        L, D, w = 8, 16, 4
        x = jax.random.normal(jax.random.PRNGKey(0), (1, L, D))
        ffn = lambda t: jnp.tanh(t @ jax.random.normal(
            jax.random.PRNGKey(1), (D, D)))
        crit = jnp.asarray([[1, 0, 1, 0, 1, 1, 1, 0]], bool)
        lead = jnp.asarray([[0, 0, 2, 2, 4, 5, 6, 6]], jnp.int32)
        plan = self._plan(crit, lead, L)
        dense = ffn(x)                               # per-row ground truth
        out = spls_ffn_packed(x, ffn, plan, 3, window=w)
        out = np.asarray(out)
        # packed rows + their followers: exact leader outputs
        for r, ld in ((0, 0), (1, 0), (2, 2), (3, 2), (4, 4)):
            np.testing.assert_array_equal(out[0, r],
                                          np.asarray(dense[0, ld]))
        # overflow rows 5, 6 (and follower 7): window leader 4's output
        for r in (5, 6, 7):
            np.testing.assert_array_equal(out[0, r],
                                          np.asarray(dense[0, 4]))

    def test_full_capacity_equals_simulation(self):
        L, D = 16, 8
        x = jax.random.normal(jax.random.PRNGKey(2), (1, L, D))
        ffn = lambda t: t * 2.0 + 1.0
        crit = jnp.asarray([[1, 0, 0, 1] * 4], bool)
        lead = jnp.asarray([[0, 0, 0, 3, 4, 4, 4, 7,
                             8, 8, 8, 11, 12, 12, 12, 15]], jnp.int32)
        lead = jnp.where(crit, jnp.arange(L), lead).astype(jnp.int32)
        plan = self._plan(crit, lead, L)
        np.testing.assert_array_equal(
            np.asarray(spls_ffn_packed(x, ffn, plan, L, window=4)),
            np.asarray(spls_ffn(x, ffn, plan)))


# ---------------------------------------------------------------------------
# packed projections vs the dense model path
# ---------------------------------------------------------------------------

class TestPackedOps:
    @pytest.mark.parametrize("kv,heads", [(2, 4), (4, 4), (1, 4)])
    @pytest.mark.parametrize("backend", ["packed_xla", "packed_pallas"])
    def test_packed_project_q_bitwise(self, kv, heads, backend):
        """GQA head counts: packed Q rows == dense project_qkv rows."""
        from repro.models.attention import project_qkv

        cfg = _spls_cfg(n_heads=heads, n_kv_heads=kv, qk_norm=True)
        p = jax.tree.map(lambda a: a[0],
                         _params(cfg)["periods"][0])["attn"]
        L, C = 16, 6
        xn = jax.random.normal(jax.random.PRNGKey(3), (1, L, cfg.d_model))
        positions = jnp.arange(10, 10 + L, dtype=jnp.int32)
        perm = jnp.asarray([0, 3, 7, 8, 12, 15], jnp.int32)
        q_full, _, _ = project_qkv(cfg, p, xn, positions[None, :],
                                   "structured")
        want = np.asarray(gather_rows(q_full, jnp.broadcast_to(
            perm, (1, kv, heads // kv, C))))
        got = np.asarray(packed_project_q(cfg, p, xn, positions, perm,
                                          backend))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("backend", ["packed_xla", "packed_pallas"])
    @pytest.mark.parametrize("B", [1, 2])
    def test_packed_mlp_full_capacity_bitwise(self, backend, B):
        from repro.models.moe import mlp_forward

        cfg = _spls_cfg()
        p = jax.tree.map(lambda a: a[0],
                         _params(cfg)["periods"][0])["ffn"]
        L = 8
        x = jax.random.normal(jax.random.PRNGKey(4), (B, L, cfg.d_model))
        crit = jnp.tile(jnp.asarray([[1, 0, 1, 0, 1, 1, 0, 0]], bool),
                        (B, 1))
        lead = jnp.tile(jnp.asarray([[0, 0, 2, 2, 4, 5, 5, 4]], jnp.int32),
                        (B, 1))
        comp = compact_rows(crit, L, leader=lead, window=4)
        got = np.asarray(packed_mlp(cfg, p, x, comp, backend))
        dense = mlp_forward(cfg, p, x)
        want = np.asarray(gather_rows(dense, lead))
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# capacity controller + registry + accounting
# ---------------------------------------------------------------------------

class TestCapacityController:
    def test_conservative_until_observed(self):
        cc = CapacityController(64)
        assert cc.capacity() == 64

    def test_buckets_and_margin(self):
        cc = CapacityController(64, margin=1.0)
        assert cc.buckets == (16, 32, 48, 64)
        cc.observe(10)
        assert cc.capacity() == 16
        for _ in range(8):
            cc.observe(40)          # EMA climbs -> larger bucket
        assert cc.capacity() == 48
        assert cc.stats["observations"] == 9

    def test_custom_buckets_always_include_total(self):
        cc = CapacityController(64, buckets=(8, 200))
        assert cc.buckets == (8, 64)

    def test_margin_overshoot_clamps_to_total(self):
        cc = CapacityController(16, margin=4.0)
        cc.observe(15)
        assert cc.capacity() == 16


class TestRegistryAndAccounting:
    def test_registry_names(self):
        assert available_compute_backends() == ("dense", "packed_pallas",
                                                "packed_xla")

    def test_resolve(self):
        assert resolve_compute_backend(None, sparse=False) == "dense"
        assert resolve_compute_backend("auto", sparse=True,
                                       platform="cpu") == "packed_xla"
        assert resolve_compute_backend("auto", sparse=True,
                                       platform="tpu") == "packed_pallas"
        with pytest.raises(ValueError, match="spls.enabled"):
            resolve_compute_backend("packed_xla", sparse=False)
        with pytest.raises(ValueError, match="unknown compute backend"):
            resolve_compute_backend("nope", sparse=True)

    def test_chunk_flops_components(self):
        cfg = _spls_cfg()
        full = chunk_flops(cfg, 16, 32)
        packed = chunk_flops(cfg, 16, 32, q_rows=8, ffn_rows=4)
        for c in ("qkv", "attn", "ffn"):
            assert full[c][0] == full[c][1] > 0
            assert packed[c][1] < packed[c][0] == full[c][0]
        # K/V + Wo share of qkv stays dense: halving q rows saves < half
        assert packed["qkv"][1] > packed["qkv"][0] / 2
        # attention scales with the packed q rows exactly
        assert packed["attn"][1] == full["attn"][0] / 2

    def test_scheduler_lifetime_accounting(self):
        from repro.serving import PagePool, Scheduler, SchedulerConfig

        sched = Scheduler(SchedulerConfig(), PagePool(8, 4), 32)
        assert sched.flops_saved_pct() == {"qkv": 0.0, "attn": 0.0,
                                           "ffn": 0.0}
        sched.note_flops({"qkv": (100.0, 50.0), "attn": (10.0, 10.0),
                          "ffn": (40.0, 10.0)})
        sched.note_flops({"qkv": (100.0, 50.0), "attn": (10.0, 10.0),
                          "ffn": (40.0, 30.0)})
        pct = sched.flops_saved_pct()
        assert pct["qkv"] == 50.0 and pct["attn"] == 0.0
        assert pct["ffn"] == 50.0


# ---------------------------------------------------------------------------
# engine-level parity + config plumbing
# ---------------------------------------------------------------------------

def _reqs(cfg, lens, max_new=4, seed0=10):
    return [Request(rid=i, prompt=jax.random.randint(
        jax.random.PRNGKey(seed0 + i), (lp,), 0, cfg.vocab_size),
        max_new_tokens=max_new) for i, lp in enumerate(lens)]


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_ticks=3000)
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


class TestPackedServingEngine:
    def _run(self, cfg, compute_backend, lens=(20, 28, 12), chunk=8,
             **scfg_kw):
        scfg = ServeConfig(n_slots=3, max_len=64, page_size=4,
                           prefill_chunk=chunk,
                           attn_backend="xla_paged_decode",
                           compute_backend=compute_backend, **scfg_kw)
        eng = PagedServingEngine(cfg, _params(cfg), scfg)
        return _drain(eng, _reqs(cfg, lens)), eng

    @pytest.mark.parametrize("backend", ["packed_xla", "packed_pallas"])
    def test_bitwise_parity_at_full_capacity(self, backend):
        """Acceptance: packed serving prefill at capacity == L (the chunk
        size bucket) produces greedy outputs bit-for-bit equal to
        simulation-mode (dense-compute) SPLS."""
        cfg = _spls_cfg()
        dense, _ = self._run(cfg, "dense")
        packed, eng = self._run(cfg, backend, capacity_buckets=(8,))
        assert packed == dense
        assert eng.stats["compute_backend"] == backend

    def test_adaptive_buckets_complete_and_save_flops(self):
        """Reduced capacities: everything drains, FFN savings accrue, and
        the controller's stats reflect the bucket choices."""
        cfg = _spls_cfg(spls_kw=dict(s_threshold=0.95))
        outs, eng = self._run(cfg, "packed_xla", lens=(48, 48, 32),
                              chunk=16, capacity_margin=1.0)
        assert all(len(o) == 4 for o in outs)
        saved = eng.stats["flops_saved_pct"]
        assert saved["ffn"] > 0.0
        assert sum(eng.stats["capacity_q"]["picks"].values()) > 0

    def test_packed_without_spls_raises(self):
        cfg = _cfg()
        with pytest.raises(ValueError, match="spls.enabled"):
            PagedServingEngine(cfg, _params(cfg), ServeConfig(
                n_slots=2, max_len=64, page_size=4,
                compute_backend="packed_xla"))

    def test_dense_engine_warns_on_packed_backend(self):
        """The dense fixed-slot engine has no packed path: a requested
        packed backend warns loudly instead of silently measuring dense."""
        from repro.serving import ServingEngine

        cfg = _spls_cfg()
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            ServingEngine(cfg, _params(cfg), ServeConfig(
                n_slots=2, max_len=64, compute_backend="packed_xla"))
        assert any("dense compute" in str(x.message) for x in w)

    def test_misaligned_chunk_raises_naming_both(self):
        cfg = _spls_cfg()
        with pytest.raises(ValueError) as ei:
            PagedServingEngine(cfg, _params(cfg), ServeConfig(
                n_slots=2, max_len=64, page_size=4, prefill_chunk=6))
        assert "6" in str(ei.value) and "4" in str(ei.value)

    def test_auto_align_chunk_rounds_up_with_warning(self):
        cfg = _spls_cfg()
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            eng = PagedServingEngine(cfg, _params(cfg), ServeConfig(
                n_slots=2, max_len=64, page_size=4, prefill_chunk=6,
                auto_align_chunk=True))
        assert eng.scfg.prefill_chunk == 8
        assert any("auto_align_chunk" in str(x.message) for x in w)
        # aligned chunk serves correctly
        outs = _drain(eng, _reqs(cfg, (20, 12)))
        assert all(len(o) == 4 for o in outs)

    def test_function_level_alignment_error(self):
        from repro.serving import paged_prefill_chunk_spls

        cfg = _spls_cfg()
        with pytest.raises(ValueError, match="multiple"):
            jax.eval_shape(
                lambda t: paged_prefill_chunk_spls(
                    cfg, None, None, None, None, None,
                    jnp.int32(0), t, jnp.int32(6), jnp.int32(2)),
                jax.ShapeDtypeStruct((1, 6), jnp.int32))


class TestDeprecatedShim:
    def test_runtime_serve_warns_and_forwards(self):
        import importlib
        import repro.runtime.serve as shim

        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            importlib.reload(shim)
            cls = shim.PagedServingEngine
        from repro.serving import PagedServingEngine as real
        assert cls is real
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
