"""Paged serving subsystem: engine parity vs the dense fixed-slot engine,
scheduler policy (chunked-prefill fairness, pool exhaustion -> queueing /
preemption, block-table reuse), SPLS page pruning, and sampling."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.spls import SPLSConfig
from repro.models import init_params
from repro.serving import (PagePool, PagedServingEngine, Request, ServeConfig,
                           ServingEngine, spls_token_keep)

jax.config.update("jax_platform_name", "cpu")

_PARAMS_CACHE = {}


def _cfg(**kw):
    base = dict(name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=64, period=(BlockCfg(),),
                remat=False)
    base.update(kw)
    return ArchConfig(**base)


def _params(cfg):
    key = (cfg.name, cfg.period, cfg.spls.enabled)
    if key not in _PARAMS_CACHE:
        _PARAMS_CACHE[key] = init_params(cfg, jax.random.PRNGKey(0))
    return _PARAMS_CACHE[key]


def _reqs(cfg, lens, max_new=5, seed0=0):
    return [Request(rid=i, prompt=jax.random.randint(
        jax.random.PRNGKey(seed0 + i), (lp,), 0, cfg.vocab_size),
        max_new_tokens=max_new) for i, lp in enumerate(lens)]


def _drain_outputs(engine, reqs):
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained(max_ticks=2000)
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


# ---------------------------------------------------------------------------
# paged vs dense parity
# ---------------------------------------------------------------------------

class TestPagedDenseParity:
    @pytest.mark.parametrize("backend", ["xla_paged_decode",
                                         "pallas_paged_decode"])
    def test_ragged_gqa(self, backend):
        """Greedy outputs bit-for-bit identical across ragged prompt
        lengths and GQA (n_heads=4, kv=2), both paged backends."""
        cfg = _cfg()
        params = _params(cfg)
        dense = _drain_outputs(
            ServingEngine(cfg, params, ServeConfig(n_slots=2, max_len=32)),
            _reqs(cfg, [12, 7, 19, 3, 14]))
        paged = _drain_outputs(
            PagedServingEngine(cfg, params, ServeConfig(
                n_slots=2, max_len=32, page_size=4, attn_backend=backend)),
            _reqs(cfg, [12, 7, 19, 3, 14]))
        assert dense == paged

    def test_sliding_window(self):
        cfg = _cfg(name="tiny-swa", period=(BlockCfg(window=6),))
        params = _params(cfg)
        dense = _drain_outputs(
            ServingEngine(cfg, params, ServeConfig(n_slots=2, max_len=32)),
            _reqs(cfg, [15, 9, 21]))
        for backend in ("xla_paged_decode", "pallas_paged_decode"):
            paged = _drain_outputs(
                PagedServingEngine(cfg, params, ServeConfig(
                    n_slots=2, max_len=32, page_size=4,
                    attn_backend=backend)),
                _reqs(cfg, [15, 9, 21]))
            assert dense == paged, backend

    def test_spls_prefill_no_prune(self):
        """SPLS-enabled prefill (sparse compute) with page pruning off:
        paged engines must reproduce the dense engine exactly."""
        cfg = _cfg(name="tiny-spls", spls=SPLSConfig(
            enabled=True, k_ratio=0.25, s_threshold=0.6, f_threshold=2,
            window=4, causal=True))
        params = _params(cfg)
        dense = _drain_outputs(
            ServingEngine(cfg, params, ServeConfig(n_slots=2, max_len=32)),
            _reqs(cfg, [16, 11, 14], max_new=4))
        for backend in ("xla_paged_decode", "pallas_paged_decode"):
            paged = _drain_outputs(
                PagedServingEngine(cfg, params, ServeConfig(
                    n_slots=2, max_len=32, page_size=4, attn_backend=backend,
                    spls_page_prune=False)),
                _reqs(cfg, [16, 11, 14], max_new=4))
            assert dense == paged, backend

    def test_spls_pruned_backends_agree_and_save_pages(self):
        """With SPLS page pruning on, both paged backends agree bit-for-bit
        and the pool peak is strictly below the unpruned run."""
        cfg = _cfg(name="tiny-spls", spls=SPLSConfig(
            enabled=True, k_ratio=0.12, s_threshold=0.6, f_threshold=2,
            window=4, causal=True))
        params = _params(cfg)
        outs, peaks = {}, {}
        for prune in (False, True):
            for backend in ("xla_paged_decode", "pallas_paged_decode"):
                eng = PagedServingEngine(cfg, params, ServeConfig(
                    n_slots=2, max_len=80, page_size=4, attn_backend=backend,
                    spls_page_prune=prune, spls_prune_vote=1.0))
                outs[(prune, backend)] = _drain_outputs(
                    eng, _reqs(cfg, [64, 48, 56], max_new=4))
                peaks[(prune, backend)] = eng.stats["peak_pages"]
        for prune in (False, True):
            assert outs[(prune, "xla_paged_decode")] == \
                outs[(prune, "pallas_paged_decode")]
        assert peaks[(True, "xla_paged_decode")] < \
            peaks[(False, "xla_paged_decode")]

    def test_chunked_prefill_parity(self):
        """Prompts longer than the chunk prefill incrementally; outputs
        stay identical to the dense whole-prompt engine."""
        cfg = _cfg()
        params = _params(cfg)
        dense = _drain_outputs(
            ServingEngine(cfg, params, ServeConfig(n_slots=2, max_len=48)),
            _reqs(cfg, [30, 7, 25]))
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=48, page_size=4, prefill_chunk=8,
            attn_backend="xla_paged_decode"))
        paged = _drain_outputs(eng, _reqs(cfg, [30, 7, 25]))
        assert eng.stats["prefill_chunks"] >= 4  # 30 -> 4 chunks of 8
        assert dense == paged


# ---------------------------------------------------------------------------
# scheduler policy
# ---------------------------------------------------------------------------

class TestSchedulerPolicy:
    def test_chunked_prefill_fairness(self):
        """Decode ticks keep producing tokens while a long prompt
        prefills chunk by chunk (no head-of-line blocking)."""
        cfg = _cfg()
        params = _params(cfg)
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=64, page_size=4, prefill_chunk=4,
            attn_backend="xla_paged_decode"))
        short = _reqs(cfg, [6], max_new=12)[0]
        long = Request(rid=99, prompt=jax.random.randint(
            jax.random.PRNGKey(99), (40,), 0, cfg.vocab_size),
            max_new_tokens=2)
        eng.submit(short)
        eng.tick()  # short admits + prefills, starts decoding
        eng.submit(long)
        overlap = 0
        for _ in range(8):  # long needs 10 chunk ticks; short decodes along
            before = len(short.output)
            eng.tick()
            still_prefilling = any(
                s is not None and s.req is long and s.phase == "prefill"
                for s in eng.sched.slots)
            if len(short.output) > before and still_prefilling:
                overlap += 1
        assert overlap >= 6, overlap
        eng.run_until_drained(max_ticks=500)
        assert short.done and long.done

    def test_pool_exhaustion_queues_admission(self):
        """With pages for only one sequence, requests run one at a time
        (admission deferred), and all still complete."""
        cfg = _cfg()
        params = _params(cfg)
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=4, max_len=24, page_size=4, n_pages=7,  # 6 usable
            attn_backend="xla_paged_decode"))
        reqs = _reqs(cfg, [16, 16, 16], max_new=4)
        outs = _drain_outputs(eng, reqs)
        assert eng.stats["admitted"] >= 3
        # never more than one sequence's pages in flight
        assert eng.stats["peak_pages"] <= 6
        dense = _drain_outputs(
            ServingEngine(cfg, params, ServeConfig(n_slots=4, max_len=24)),
            _reqs(cfg, [16, 16, 16], max_new=4))
        assert outs == dense

    def test_preemption_by_page_eviction(self):
        """A dry pool evicts the youngest sequence's pages; recompute-style
        resume keeps greedy outputs identical to the dense engine."""
        cfg = _cfg()
        params = _params(cfg)
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=3, max_len=32, page_size=4, n_pages=9,  # 8 usable
            attn_backend="xla_paged_decode"))
        reqs = _reqs(cfg, [12, 12, 12], max_new=6)
        outs = _drain_outputs(eng, reqs)
        assert eng.stats["preemptions"] > 0
        dense = _drain_outputs(
            ServingEngine(cfg, params, ServeConfig(n_slots=3, max_len=32)),
            _reqs(cfg, [12, 12, 12], max_new=6))
        assert outs == dense

    def test_block_table_reuse_after_retirement(self):
        """Pages freed by retirement are reallocated to later requests:
        total distinct pages touched stays bounded by the pool, and the
        pool drains back to empty."""
        cfg = _cfg()
        params = _params(cfg)
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=1, max_len=24, page_size=4, n_pages=7,
            attn_backend="xla_paged_decode"))
        seen_pages = set()
        reqs = _reqs(cfg, [14, 14, 14, 14], max_new=3)
        for r in reqs:
            eng.submit(r)
        for _ in range(400):
            eng.tick()
            for st in eng.sched.active():
                seen_pages.update(st.pages)
            if eng.sched.idle():
                break
        assert all(r.done for r in reqs)
        # 4 requests x 5 pages each = 20 page-uses through <= 6 physical
        assert len(seen_pages) <= 6
        assert eng.stats["pages_in_use"] == 0
        assert eng.pool.free_pages == eng.pool.capacity

    def test_oversized_request_rejected(self):
        cfg = _cfg()
        params = _params(cfg)
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=32, page_size=4, n_pages=4))
        with pytest.raises(ValueError):
            eng.submit(_reqs(cfg, [20], max_new=8)[0])

    def test_pool_allocator(self):
        pool = PagePool(6, 4)
        assert pool.capacity == 5
        a = pool.alloc(3)
        assert a is not None and 0 not in a
        assert pool.alloc(3) is None          # all-or-nothing
        assert pool.pages_in_use == 3
        pool.free(a)
        assert pool.free_pages == 5
        assert pool.pages_for(9) == 3


# ---------------------------------------------------------------------------
# satellites: run_until_drained return value + sampling
# ---------------------------------------------------------------------------

class TestEngineApi:
    def test_run_until_drained_returns_retired(self):
        cfg = _cfg()
        params = _params(cfg)
        for eng in (ServingEngine(cfg, params,
                                  ServeConfig(n_slots=2, max_len=32)),
                    PagedServingEngine(cfg, params, ServeConfig(
                        n_slots=2, max_len=32, page_size=4))):
            reqs = _reqs(cfg, [8, 5, 11], max_new=3)
            for r in reqs:
                eng.submit(r)
            done = eng.run_until_drained()
            assert sorted(r.rid for r in done) == [0, 1, 2]
            assert all(r.done for r in done)
            # a second call returns only newly retired requests
            assert eng.run_until_drained() == []

    @pytest.mark.parametrize("engine_cls", [ServingEngine,
                                            PagedServingEngine])
    def test_temperature_sampling(self, engine_cls):
        """greedy=False samples through the threaded PRNG key:
        deterministic per seed, different across seeds, and (at high
        temperature) different from greedy argmax."""
        cfg = _cfg()
        params = _params(cfg)

        def run(greedy, temperature, seed):
            eng = engine_cls(cfg, params, ServeConfig(
                n_slots=2, max_len=48, page_size=4, greedy=greedy,
                temperature=temperature, seed=seed))
            return _drain_outputs(eng, _reqs(cfg, [10, 10], max_new=12))

        greedy = run(True, 1.0, 0)
        s0 = run(False, 8.0, 0)
        s0b = run(False, 8.0, 0)
        s1 = run(False, 8.0, 1)
        assert s0 == s0b                      # seeded => deterministic
        assert s0 != s1                       # seed changes the draw
        assert s0 != greedy                   # hot sampling leaves argmax
        # greedy must be unaffected by seed (regression: flag not dead)
        assert run(True, 8.0, 7) == greedy

    def test_eos_retires_early(self):
        cfg = _cfg()
        params = _params(cfg)
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=1, max_len=32, page_size=4))
        r = _reqs(cfg, [9], max_new=20)[0]
        eng.submit(r)
        eng.run_until_drained(max_ticks=50)
        first = list(r.output)
        # rerun with eos set to the first emitted token
        eng2 = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=1, max_len=32, page_size=4))
        r2 = _reqs(cfg, [9], max_new=20)[0]
        r2.eos_id = first[0]
        eng2.submit(r2)
        eng2.run_until_drained(max_ticks=50)
        assert r2.done and len(r2.output) == 1
