"""Tiny vendored stand-in for the ``hypothesis`` API surface these tests use.

The pinned container has no ``hypothesis`` package, and tier-1 must collect
and pass with nothing beyond the baked-in environment.  This shim keeps the
test bodies untouched: it provides ``given``/``settings`` decorators and the
``strategies`` used here (integers, floats, lists), drawing *deterministic*
seeded pseudo-random examples instead of hypothesis' adaptive search.  No
shrinking, no database -- just N reproducible examples per test.

Usage (drop-in for the subset we need)::

    from _propcheck import given, settings
    from _propcheck import strategies as st

    @given(st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_prop(self, v): ...
"""

from __future__ import annotations

import random
import zlib
from typing import Any, Callable, List, Optional

__all__ = ["given", "settings", "strategies", "integers", "floats", "lists"]

_DEFAULT_MAX_EXAMPLES = 16
_SEED = 0xE5AC7  # stable across runs: failures are reproducible


class _Strategy:
    def draw(self, rng: random.Random) -> Any:  # pragma: no cover - interface
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value: Optional[int] = None,
                 max_value: Optional[int] = None):
        self.lo = -(2 ** 31) if min_value is None else min_value
        self.hi = 2 ** 31 if max_value is None else max_value

    def draw(self, rng: random.Random) -> int:
        return rng.randint(self.lo, self.hi)


class _Floats(_Strategy):
    def __init__(self, min_value: Optional[float] = None,
                 max_value: Optional[float] = None,
                 allow_nan: bool = False, allow_infinity: bool = False):
        self.lo = -1e9 if min_value is None else float(min_value)
        self.hi = 1e9 if max_value is None else float(max_value)

    def draw(self, rng: random.Random) -> float:
        return rng.uniform(self.lo, self.hi)


class _Lists(_Strategy):
    def __init__(self, elements: _Strategy, min_size: int = 0,
                 max_size: int = 32):
        self.elements = elements
        self.min_size, self.max_size = min_size, max_size

    def draw(self, rng: random.Random) -> List[Any]:
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.draw(rng) for _ in range(n)]


def integers(min_value: Optional[int] = None,
             max_value: Optional[int] = None) -> _Strategy:
    return _Integers(min_value, max_value)


def floats(min_value: Optional[float] = None,
           max_value: Optional[float] = None, *,
           allow_nan: bool = False,
           allow_infinity: bool = False) -> _Strategy:
    return _Floats(min_value, max_value, allow_nan, allow_infinity)


def lists(elements: _Strategy, *, min_size: int = 0,
          max_size: int = 32) -> _Strategy:
    return _Lists(elements, min_size, max_size)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES,
             deadline: Any = None, **_ignored) -> Callable:
    """Attach example-count metadata; composes with :func:`given` in either
    decorator order (hypothesis allows both)."""

    def deco(fn: Callable) -> Callable:
        fn._propcheck_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(*strats: _Strategy) -> Callable:
    """Run the test once per drawn example tuple.

    The wrapper exposes a fixture-free ``(*args, **kwargs)`` signature so
    pytest passes only ``self`` (for methods); drawn values are appended.
    """

    def deco(fn: Callable) -> Callable:
        def wrapper(*args, **kwargs):
            cfg = getattr(fn, "_propcheck_settings", None) or \
                getattr(wrapper, "_propcheck_settings", None) or {}
            n = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                # str hashing is salted per-process; crc32 keeps the draw
                # sequence identical across runs and machines
                rng = random.Random(
                    _SEED ^ zlib.crc32(fn.__qualname__.encode()) ^ (i * 9973))
                drawn = tuple(s.draw(rng) for s in strats)
                try:
                    fn(*args, *drawn, **kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example #{i}: args={drawn!r}") from e
            return None

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


# ``from tests._propcheck import strategies as st`` mirror of hypothesis
class _StrategiesNamespace:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    lists = staticmethod(lists)


strategies = _StrategiesNamespace()
