"""Tests for the SPLS pipeline: top-k, local similarity, MFI, plan, exec."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings
from _propcheck import strategies as st

from repro.core import (SPLSConfig, build_plan, dense_flops, gather_rows,
                        kv_keep_from_mask, local_similarity, mfi_ffn_sparsity,
                        pack_by_mask, plan_stats, predicted_attention,
                        reduction_report, row_topk_mask, sparsify_pam,
                        spls_attention, spls_attention_packed, spls_ffn,
                        spls_ffn_packed, spls_flops, topk_count,
                        unpack_by_leader, windowed_l1)

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)


def _rand(shape, k=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(k), shape) * scale


class TestTopK:
    def test_exact_k_per_row(self):
        x = _rand((3, 4, 16, 16), 1)
        mask = row_topk_mask(x, 5)
        np.testing.assert_array_equal(np.asarray(mask.sum(-1)), 5)

    def test_keeps_largest(self):
        x = jnp.asarray([[1.0, 5.0, 3.0, -2.0]])
        mask = row_topk_mask(x, 2)
        np.testing.assert_array_equal(np.asarray(mask[0]), [False, True, True, False])

    def test_k_geq_L_keeps_all(self):
        x = _rand((2, 8), 2)
        assert bool(row_topk_mask(x, 8).all())
        assert bool(row_topk_mask(x, 100).all())

    def test_topk_count(self):
        assert topk_count(128, 0.12) == 16  # ceil(15.36)
        assert topk_count(128, 0.0) == 1
        assert topk_count(128, 2.0) == 128

    def test_spa_zeroes_dropped(self):
        pam = _rand((1, 2, 32, 32), 3)
        spa, mask = sparsify_pam(pam, 0.25)
        assert float(jnp.abs(jnp.where(mask, 0.0, spa)).max()) == 0.0
        np.testing.assert_allclose(np.asarray(spa[mask]), np.asarray(pam[mask]))

    def test_kv_keep_column_semantics(self):
        mask = jnp.zeros((1, 1, 4, 6), bool).at[0, 0, :, 2].set(True)
        keep = kv_keep_from_mask(mask)
        np.testing.assert_array_equal(
            np.asarray(keep[0, 0]), [False, False, True, False, False, False])


class TestLocalSimilarity:
    def test_identical_rows_cluster(self):
        row = _rand((1, 16), 4)
        spa = jnp.tile(row, (8, 1))[None]  # one window of 8 identical rows
        sim = local_similarity(spa, w=8, s=0.1)
        assert int(sim.is_critical.sum()) == 1
        np.testing.assert_array_equal(np.asarray(sim.leader[0]), 0)

    def test_orthogonal_rows_all_critical(self):
        spa = jnp.eye(8)[None]  # disjoint supports -> L1 distance maximal
        sim = local_similarity(spa, w=8, s=0.5)
        assert bool(sim.is_critical.all())
        np.testing.assert_array_equal(np.asarray(sim.leader[0]), np.arange(8))

    def test_leader_precedes_follower_within_window(self):
        spa = _rand((2, 3, 64, 64), 5)
        sim = local_similarity(spa, w=8, s=0.9)
        lead = np.asarray(sim.leader)
        rows = np.broadcast_to(np.arange(64), lead.shape)
        assert (lead <= rows).all()
        assert (lead // 8 == rows // 8).all()  # same window

    def test_critical_iff_self_leader(self):
        spa = _rand((1, 2, 40, 40), 6)
        sim = local_similarity(spa, w=8, s=0.7)
        rows = np.broadcast_to(np.arange(40), sim.leader.shape)
        np.testing.assert_array_equal(np.asarray(sim.is_critical),
                                      np.asarray(sim.leader) == rows)

    def test_leaders_are_critical(self):
        spa = _rand((1, 4, 64, 64), 7)
        sim = local_similarity(spa, w=8, s=0.95)
        crit = np.asarray(sim.is_critical)
        lead = np.asarray(sim.leader)
        assert np.take_along_axis(crit, lead, axis=-1).all()

    def test_s_monotone_sparsity(self):
        spa, _ = sparsify_pam(_rand((2, 4, 128, 128), 8), 0.2)
        frac = []
        for s in (0.1, 0.5, 0.9):
            sim = local_similarity(spa, w=8, s=s)
            frac.append(float(sim.is_critical.mean()))
        assert frac[0] >= frac[1] >= frac[2]

    def test_window_partition_ragged_tail(self):
        spa = _rand((1, 1, 13, 13), 9)  # L=13, w=8 -> windows [8, 5]
        sim = local_similarity(spa, w=8, s=0.9, valid_len=13)
        assert sim.leader.shape == (1, 1, 13)
        assert int(sim.leader.max()) <= 12

    def test_windowed_l1_symmetric_zero_diag(self):
        d = windowed_l1(_rand((2, 2, 32, 32), 10), 8)
        np.testing.assert_allclose(np.asarray(d), np.asarray(d.swapaxes(-1, -2)),
                                   atol=1e-6)
        assert float(jnp.abs(jnp.diagonal(d, axis1=-2, axis2=-1)).max()) < 1e-6
        assert float(d.min()) >= 0 and float(d.max()) <= 1.0 + 1e-6


class TestMFI:
    def test_unanimous_heads_make_similar(self):
        # 4 heads, 8 tokens, every head says token t follows token 0
        leader = jnp.zeros((4, 8), jnp.int32)[None]
        out = mfi_ffn_sparsity(leader, w=8, f_threshold=4)
        np.testing.assert_array_equal(np.asarray(out.leader[0]), 0)
        assert int(out.is_critical.sum()) == 1

    def test_threshold_blocks_vote(self):
        # 2-of-4 heads vote token1 -> 0; f=3 rejects, f=2 accepts
        leader = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (1, 4, 8))
        leader = leader.at[0, :2, 1].set(0)
        rej = mfi_ffn_sparsity(leader, w=8, f_threshold=3)
        assert bool(rej.is_critical[0, 1])
        acc = mfi_ffn_sparsity(leader, w=8, f_threshold=2)
        assert not bool(acc.is_critical[0, 1])
        assert int(acc.leader[0, 1]) == 0

    def test_f_monotone_sparsity(self):
        spa, _ = sparsify_pam(_rand((2, 8, 64, 64), 11), 0.15)
        sim = local_similarity(spa, w=8, s=0.8)
        dens = [float(mfi_ffn_sparsity(sim.leader, 8, f).is_critical.mean())
                for f in (2, 4, 8)]
        assert dens[0] <= dens[1] <= dens[2]

    def test_ffn_leaders_are_ffn_critical(self):
        spa, _ = sparsify_pam(_rand((1, 8, 64, 64), 12), 0.15)
        sim = local_similarity(spa, w=8, s=0.9)
        out = mfi_ffn_sparsity(sim.leader, 8, 3)
        crit = np.asarray(out.is_critical)
        lead = np.asarray(out.leader)
        assert np.take_along_axis(crit, lead, axis=-1).all()


class TestPlan:
    def _plan(self, B=2, L=64, D=64, H=4, **kw):
        cfg = SPLSConfig(**kw)
        x = _rand((B, L, D), 13)
        wq = _rand((D, D), 14, 0.1)
        wk = _rand((D, D), 15, 0.1)
        return build_plan(x, wq, wk, H, cfg), cfg

    def test_disabled_is_dense(self):
        plan, _ = self._plan(enabled=False, causal=False)
        assert bool(plan.attn_mask.all())
        assert bool(plan.q_critical.all()) and bool(plan.ffn_critical.all())

    def test_causal_never_selects_future(self):
        plan, _ = self._plan(causal=True)
        iu = np.triu_indices(64, k=1)
        assert not np.asarray(plan.attn_mask)[..., iu[0], iu[1]].any()

    def test_stats_in_unit_interval(self):
        plan, _ = self._plan(k_ratio=0.2, s_threshold=0.7, f_threshold=2)
        for k, v in plan_stats(plan).items():
            assert 0.0 <= float(v) <= 1.0, k

    def test_flops_reduction_positive_under_sparsity(self):
        plan, _ = self._plan(k_ratio=0.12, s_threshold=0.9, f_threshold=2)
        rep = reduction_report(plan, 64, 256)
        assert float(rep["attention_reduction"]) > 0.5
        assert float(rep["qkv_reduction"]) > 0.0
        assert float(rep["ffn_reduction"]) >= 0.0

    def test_dense_plan_flops_match_formula(self):
        plan, _ = self._plan(enabled=False, causal=False)
        got = spls_flops(plan, 64, 256, include_overhead=False)
        want = dense_flops(2, 64, 64, 4, 256, causal=False)
        np.testing.assert_allclose(float(got.qkv), float(want.qkv))
        np.testing.assert_allclose(float(got.attention), float(want.attention))
        np.testing.assert_allclose(float(got.ffn), float(want.ffn))


class TestSparseExec:
    def _setup(self, B=2, H=4, L=64, Dh=16, s=0.8, k_ratio=0.15):
        D = H * Dh
        x = _rand((B, L, D), 20)
        plan, _ = TestPlan()._plan(B=B, L=L, D=D, H=H,
                                   k_ratio=k_ratio, s_threshold=s,
                                   f_threshold=2)
        q = _rand((B, H, L, Dh), 21)
        k = _rand((B, H, L, Dh), 22)
        v = _rand((B, H, L, Dh), 23)
        return x, plan, q, k, v

    def test_packed_equals_simulation_at_full_capacity(self):
        x, plan, q, k, v = self._setup()
        o_sim = spls_attention(q, k, v, plan)
        o_pack = spls_attention_packed(q, k, v, plan, 64, 64)
        np.testing.assert_allclose(np.asarray(o_sim), np.asarray(o_pack),
                                   atol=1e-5)

    def test_similar_rows_copy_leader_output(self):
        x, plan, q, k, v = self._setup()
        out = np.asarray(spls_attention(q, k, v, plan))
        lead = np.asarray(plan.q_leader)
        for b in range(2):
            for h in range(4):
                np.testing.assert_allclose(out[b, h], out[b, h][lead[b, h]])

    def test_ffn_packed_equals_simulation(self):
        x, plan, q, k, v = self._setup()
        w = _rand((64, 64), 24, 0.1)
        fn = lambda t: jax.nn.gelu(t @ w)
        np.testing.assert_allclose(
            np.asarray(spls_ffn(x, fn, plan)),
            np.asarray(spls_ffn_packed(x, fn, plan, 64)), atol=1e-5)

    def test_reduced_capacity_runs_and_matches_on_critical(self):
        x, plan, q, k, v = self._setup(s=0.95, k_ratio=0.1)
        ncrit = int(plan.q_critical.sum(-1).max())
        nkv = int(plan.kv_keep.sum(-1).max())
        o_sim = np.asarray(spls_attention(q, k, v, plan))
        o_pack = np.asarray(spls_attention_packed(q, k, v, plan, ncrit, nkv))
        crit = np.asarray(plan.q_critical)
        np.testing.assert_allclose(o_pack[crit], o_sim[crit], atol=1e-5)

    def test_pack_unpack_roundtrip_identity_mask(self):
        mask = jnp.ones((3, 16), bool)
        perm, slot = pack_by_mask(mask, 16)
        x = _rand((3, 16, 8), 25)
        leader = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32), (3, 16))
        y = unpack_by_leader(gather_rows(x, perm), slot, leader)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x))

    @given(st.integers(1, 6), st.integers(8, 33))
    @settings(max_examples=16, deadline=None)
    def test_pack_slots_consistent(self, seed, L):
        mask = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (L,))
        perm, slot = pack_by_mask(mask[None], L)
        perm, slot = np.asarray(perm[0]), np.asarray(slot[0])
        # every critical row's slot points back at itself through perm
        for row in range(L):
            if mask[row]:
                assert perm[slot[row]] == row

    def test_grad_flows_through_simulation_mode(self):
        x, plan, q, k, v = self._setup()
        f = lambda q_: spls_attention(q_, k, v, plan).sum()
        g = jax.grad(f)(q)
        assert np.isfinite(np.asarray(g)).all()
        # non-critical rows get no gradient (their Q is never used)...
        # unless they lead someone; critical rows always used by themselves.
        used = np.zeros(np.asarray(plan.q_leader).shape, bool)
        lead = np.asarray(plan.q_leader)
        np.put_along_axis(used, lead, True, axis=-1)
        gn = np.abs(np.asarray(g)).sum(-1)
        assert (gn[~used] == 0).all()
