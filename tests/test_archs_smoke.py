"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and no NaNs (assignment requirement f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LM_SHAPES
from repro.configs.registry import ARCH_IDS, all_cells, get_config, get_shape
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill)

jax.config.update("jax_platform_name", "cpu")

ALL_ARCHS = ARCH_IDS + ["bert-base-esact"]


def _smoke_cfg(arch_id):
    cfg = get_config(arch_id).smoke()
    # keep CPU smoke fast + fp32 numerics
    return dataclasses.replace(cfg, remat=False)


def _batch(cfg, B=2, L=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(ks[0], (B, L), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(ks[0], (B, L, cfg.d_model))
    labels = jax.random.randint(ks[1], (B, L), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch_id):
        cfg = _smoke_cfg(arch_id)
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        logits = jax.jit(lambda p, x: forward(cfg, p, x))(params,
                                                          batch["inputs"])
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"

    def test_train_step(self, arch_id):
        cfg = _smoke_cfg(arch_id)
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)

        def step(p):
            loss, metrics = loss_fn(cfg, p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.jit(
            jax.value_and_grad(step, has_aux=True))(params)
        assert np.isfinite(float(loss))
        for leaf in jax.tree.leaves(grads):
            assert bool(jnp.isfinite(leaf).all()), "non-finite gradient"

    def test_decode_step(self, arch_id):
        cfg = _smoke_cfg(arch_id)
        if not cfg.causal:
            pytest.skip("encoder arch has no decode step")
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        _, cache = jax.jit(
            lambda p, x: prefill(cfg, p, x, max_len=24))(params,
                                                         batch["inputs"])
        if cfg.input_mode == "tokens":
            tok = jnp.zeros((2, 1), jnp.int32)
        else:
            tok = jnp.zeros((2, 1, cfg.d_model))
        pos = jnp.full((2,), 16, jnp.int32)
        logits, new_cache = jax.jit(
            lambda p, c, t: decode_step(cfg, p, c, t, pos))(params, cache, tok)
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())


class TestAssignment:
    """The full configs must match the assignment table exactly."""

    TABLE = {
        # name: (L, d_model, H, KV, d_ff, vocab)
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "h2o-danube3-4b": (24, 3840, 32, 8, 10240, 32000),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    }

    @pytest.mark.parametrize("arch_id", list(TABLE))
    def test_exact_dims(self, arch_id):
        cfg = get_config(arch_id)
        L, D, H, KV, F, V = self.TABLE[arch_id]
        assert cfg.n_layers == L and cfg.d_model == D
        assert cfg.n_heads == H and cfg.n_kv_heads == KV
        assert cfg.d_ff == F and cfg.vocab_size == V

    @pytest.mark.parametrize("arch_id,lo,hi", [
        ("gemma2-27b", 26e9, 29e9), ("h2o-danube3-4b", 3.5e9, 4.5e9),
        ("qwen3-0.6b", 0.55e9, 0.8e9), ("llama3-405b", 395e9, 415e9),
        ("dbrx-132b", 125e9, 140e9), ("olmoe-1b-7b", 6.5e9, 7.5e9),
        ("musicgen-medium", 1.2e9, 1.6e9), ("mamba2-370m", 0.33e9, 0.42e9),
        ("jamba-v0.1-52b", 49e9, 55e9), ("pixtral-12b", 11.5e9, 13e9),
    ])
    def test_param_counts_match_published(self, arch_id, lo, hi):
        assert lo <= get_config(arch_id).param_count() <= hi

    def test_moe_active_params(self):
        olmoe = get_config("olmoe-1b-7b")
        assert 1.0e9 <= olmoe.active_param_count() <= 1.5e9  # "1b-7b"
        dbrx = get_config("dbrx-132b")
        assert 34e9 <= dbrx.active_param_count() <= 40e9     # "36B active"

    def test_cell_count(self):
        runnable = list(all_cells())
        everything = list(all_cells(include_skipped=True))
        assert len(everything) == 40
        assert len(runnable) == 34  # 6 long_500k skips on full-attn archs

    def test_long500k_only_on_subquadratic(self):
        for arch_id in ARCH_IDS:
            cfg = get_config(arch_id)
            sub_quadratic = (cfg.has_mamba
                             or any(b.window for b in cfg.period))
            assert (("long_500k" in cfg.supported_shapes) == sub_quadratic), \
                arch_id

    def test_moe_capacity_rounding(self):
        cfg = get_config("olmoe-1b-7b")
        c = cfg.moe_capacity(4096)
        assert c % 8 == 0 and c >= 4096 * 8 // 64

    @pytest.mark.parametrize("shape", [s.name for s in LM_SHAPES])
    def test_shapes_table(self, shape):
        s = get_shape(shape)
        table = {"train_4k": (4096, 256, "train"),
                 "prefill_32k": (32768, 32, "prefill"),
                 "decode_32k": (32768, 128, "decode"),
                 "long_500k": (524288, 1, "decode")}
        assert (s.seq_len, s.global_batch, s.kind) == table[shape]
