"""flash_decode Pallas kernel vs the dense oracle: shape/dtype/position
sweeps including sliding-window and softcap decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_decode
from repro.kernels.ref import flash_decode_ref

jax.config.update("jax_platform_name", "cpu")


def _setup(B=2, KV=2, G=4, S=1024, Dh=64, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, KV, G, Dh), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, Dh), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, Dh), dtype)
    return q, k, v


class TestFlashDecode:
    @pytest.mark.parametrize("S,bk", [(512, 256), (1024, 512), (1024, 1024),
                                      (2048, 256)])
    def test_shapes(self, S, bk):
        q, k, v = _setup(S=S)
        pos = jnp.asarray([S - 1, S // 3])
        out = flash_decode(q, k, v, pos, block_k=bk, interpret=True)
        want = flash_decode_ref(q, k, v, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q, k, v = _setup(dtype=dtype)
        pos = jnp.asarray([900, 100])
        out = flash_decode(q, k, v, pos, block_k=256, interpret=True)
        want = flash_decode_ref(q, k, v, pos)
        atol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), atol=atol)

    @pytest.mark.parametrize("window", [128, 512])
    def test_sliding_window(self, window):
        q, k, v = _setup()
        pos = jnp.asarray([1000, 300])
        out = flash_decode(q, k, v, pos, window=window, block_k=256,
                           interpret=True)
        want = flash_decode_ref(q, k, v, pos, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)

    def test_softcap(self):
        q, k, v = _setup(seed=5)
        pos = jnp.asarray([512, 700])
        out = flash_decode(q, k, v, pos, softcap=50.0, block_k=256,
                           interpret=True)
        want = flash_decode_ref(q, k, v, pos, softcap=50.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)

    def test_pos_zero(self):
        """First decode step: only slot 0 visible."""
        q, k, v = _setup(S=512)
        pos = jnp.zeros((2,), jnp.int32)
        out = flash_decode(q, k, v, pos, block_k=256, interpret=True)
        want = flash_decode_ref(q, k, v, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)

    def test_block_skipping_correct_past_pos(self):
        """Cache slots beyond pos must not contribute (garbage tolerance)."""
        q, k, v = _setup(S=1024)
        pos = jnp.asarray([100, 100])
        k_dirty = k.at[:, :, 200:].set(1e6)  # garbage beyond pos
        v_dirty = v.at[:, :, 200:].set(1e6)
        out = flash_decode(q, k_dirty, v_dirty, pos, block_k=256,
                           interpret=True)
        want = flash_decode_ref(q, k, v, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)
