"""Sharding rules (logical axes, divisibility fallback, param specs) and
the trip-count-corrected HLO analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.hlo_analysis import parse_hlo_collectives, parse_hlo_stats
from repro.launch.mesh import make_cpu_mesh
from repro.sharding.logical import axis_rules, constrain, logical_to_mesh
from repro.sharding.rules import (activation_rules, batch_sharding,
                                  param_sharding)

jax.config.update("jax_platform_name", "cpu")


class TestLogicalRules:
    def test_no_rules_is_identity_spec(self):
        spec = logical_to_mesh(["batch", "embed"], rules=None)
        assert spec == P(None, None)

    def test_basic_binding(self):
        rules = {"batch": "data", "ffn": "model"}
        spec = logical_to_mesh(["batch", None, "ffn"], rules=rules)
        assert spec == P("data", None, "model")

    def test_divisibility_fallback(self):
        mesh = make_cpu_mesh(1, 1)
        rules = {"kv": "model"}
        # dim 7 not divisible by model size -> replicated... model size is
        # 1 here so use an artificial rules check via shape gate
        spec = logical_to_mesh(["kv"], shape=[7], rules=rules, mesh=mesh)
        assert spec == P("model")  # size-1 axis always divides

    def test_duplicate_mesh_axis_dedup(self):
        rules = {"heads": "model", "ffn": "model"}
        spec = logical_to_mesh(["heads", "ffn"], rules=rules)
        assert spec == P("model", None)  # first binding wins

    def test_constrain_noop_outside_context(self):
        x = jnp.ones((4, 4))
        y = constrain(x, ("batch", "embed"))
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_constrain_inside_context(self):
        mesh = make_cpu_mesh(1, 1)
        with axis_rules(activation_rules(mesh), mesh):
            x = jnp.ones((4, 4))
            y = jax.jit(lambda a: constrain(a, ("batch", None)))(x)
            np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


class TestParamSharding:
    def test_specs_cover_all_leaves(self):
        from repro.configs.registry import get_config
        from repro.models.model import abstract_params
        cfg = get_config("qwen3-0.6b").smoke()
        mesh = make_cpu_mesh(1, 1)
        ab = abstract_params(cfg)
        shd = param_sharding(cfg, mesh, ab)
        n_ab = len(jax.tree.leaves(ab))
        n_sh = len(jax.tree.leaves(
            shd, is_leaf=lambda x: isinstance(x, NamedSharding)))
        assert n_ab == n_sh
        for s in jax.tree.leaves(
                shd, is_leaf=lambda x: isinstance(x, NamedSharding)):
            assert isinstance(s, NamedSharding)

    def test_batch_sharding_fallback(self):
        mesh = make_cpu_mesh(1, 1)
        assert batch_sharding(mesh, 8).spec == P(("data",))
        # batch=1 on data=1 divides; simulate non-divisible via prime
        assert batch_sharding(mesh, 7).spec == P(("data",))


_HLO_SAMPLE = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %c = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %x)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


class TestHLOAnalysis:
    def test_while_trip_count_multiplies(self):
        stats = parse_hlo_stats(_HLO_SAMPLE)
        # dot: 2 * 64 * 8 flops, x5 trips
        assert stats["dot_flops"] == 2 * 64 * 8 * 5
        # all-reduce result 8*8*4 bytes x5
        assert stats["coll:all-reduce"] == 8 * 8 * 4 * 5

    def test_collectives_wrapper(self):
        out = parse_hlo_collectives(_HLO_SAMPLE)
        assert out["all-reduce"] == 1280
        assert out["total"] == 1280

    def test_backend_config_trip_count_preferred(self):
        hlo = _HLO_SAMPLE.replace(
            "condition=%cond.1, body=%body.1",
            'condition=%cond.1, body=%body.1, '
            'backend_config={"known_trip_count":{"n":"7"}}')
        stats = parse_hlo_stats(hlo)
        assert stats["dot_flops"] == 2 * 64 * 8 * 7

    def test_real_compiled_program(self):
        """Analyzer vs XLA cost_analysis on an unscanned jit program."""
        def f(x, w):
            return jax.nn.relu(x @ w) @ w.T

        x = jnp.ones((32, 64))
        w = jnp.ones((64, 128))
        compiled = jax.jit(f).lower(x, w).compile()
        stats = parse_hlo_stats(compiled.as_text())
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # jax<0.5 returns [dict] per device
            ca = ca[0]
        # dots dominate; analyzer within 10% of XLA flops
        assert abs(stats["dot_flops"] - ca["flops"]) / ca["flops"] < 0.1

    def test_scanned_program_scales_with_trips(self):
        def f(x):
            w = jnp.ones((16, 16))

            def body(c, _):
                return jnp.tanh(c @ w), None

            out, _ = jax.lax.scan(body, x, None, length=10)
            return out

        compiled = jax.jit(f).lower(jnp.ones((4, 16))).compile()
        stats = parse_hlo_stats(compiled.as_text())
        assert stats["dot_flops"] == pytest.approx(2 * 4 * 16 * 16 * 10,
                                                   rel=0.01)
