"""Attention backend dispatcher: registry, auto rules, and interpret-mode
parity between every registered backend -- with and without an SPLS plan.

Parity semantics (models/README.md): without a plan all forward backends
are bit-compatible within fp32 tolerance.  With a plan, ``xla_dense`` /
``xla_packed`` realise the *simulation-mode* semantics (leader recovery +
full intra-row SPA mask) while ``pallas_flash`` / ``xla_chunked`` realise
the *hardware* semantics (leader recovery + column pruning at block
granularity; no per-element intra-row mask).  When the plan's intra-row
mask carries no information beyond causal & kv_keep, all four coincide --
the three-way equality asserted here.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.spls import SPLSConfig, SparsityPlan, build_plan
from repro.models import (attention_forward, available_backends, forward,
                          get_backend, init_attention, init_params,
                          resolve_backend)
from repro.models.attn_backend import pallas_flash, xla_chunked, xla_dense

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-5   # fp32 online-softmax vs materialized softmax


def _cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                head_dim=16, d_ff=128, vocab_size=64,
                period=(BlockCfg(),), remat=False)
    base.update(kw)
    return ArchConfig(**base)


def _qkv(B=2, H=4, L=128, Dh=16, seed=0):
    """Backend-layout tensors: q (B, H, 1, L, Dh), k/v (B, H, L, Dh)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, 1, L, Dh))
    k = jax.random.normal(ks[1], (B, H, L, Dh))
    v = jax.random.normal(ks[2], (B, H, L, Dh))
    return q, k, v


def _head_plan(B=2, H=4, L=128, D=64, seed=3, **spls_kw) -> SparsityPlan:
    """A real SPLS plan reshaped to the (B, KV=H, G=1, ...) backend layout."""
    scfg = SPLSConfig(**spls_kw)
    x = jax.random.normal(jax.random.PRNGKey(seed), (B, L, D))
    wq = jax.random.normal(jax.random.PRNGKey(seed + 1), (D, D)) * 0.1
    wk = jax.random.normal(jax.random.PRNGKey(seed + 2), (D, D)) * 0.1
    plan = build_plan(x, wq, wk, H, scfg)
    return SparsityPlan(
        attn_mask=plan.attn_mask.reshape(B, H, 1, L, L),
        q_critical=plan.q_critical.reshape(B, H, 1, L),
        q_leader=plan.q_leader.reshape(B, H, 1, L),
        kv_keep=plan.kv_keep.reshape(B, H, 1, L),
        ffn_critical=plan.ffn_critical,
        ffn_leader=plan.ffn_leader,
    )


def _column_only(plan: SparsityPlan, causal: bool) -> SparsityPlan:
    """Drop the intra-row SPA mask: attn_mask := causal & kv_keep.

    This is the regime every backend (XLA and Pallas alike) can realise
    exactly, so dense == packed == chunked == pallas holds.
    """
    L = plan.kv_keep.shape[-1]
    tri = (jnp.tril(jnp.ones((L, L), bool)) if causal
           else jnp.ones((L, L), bool))
    return plan._replace(attn_mask=tri & plan.kv_keep[..., None, :])


def _block_kill(plan: SparsityPlan, lo: int, hi: int) -> SparsityPlan:
    """Kill K/V columns [lo, hi) everywhere -- whole Pallas K blocks die."""
    keep = plan.kv_keep.at[..., lo:hi].set(False)
    keep = keep.at[..., 0].set(True)  # every causal row keeps >= 1 column
    return plan._replace(kv_keep=keep,
                         attn_mask=plan.attn_mask & keep[..., None, :])


FORWARD = sorted(available_backends(decode=False))
# contiguous-cache decode backends; the paged ones (different signature)
# are covered by tests/test_paged_decode.py
DECODE = sorted(available_backends(decode=True, paged=False))


class TestRegistry:
    def test_expected_backends_registered(self):
        assert set(FORWARD) >= {"xla_dense", "xla_packed", "xla_chunked",
                                "pallas_flash"}
        assert set(DECODE) >= {"xla_dense_decode", "pallas_flash_decode"}
        assert set(available_backends(decode=True, paged=True)) >= {
            "xla_paged_decode", "pallas_paged_decode"}

    def test_unknown_name_raises(self):
        with pytest.raises(ValueError, match="unknown attention backend"):
            get_backend("nope")
        with pytest.raises(ValueError, match="unknown attention backend"):
            resolve_backend("nope", _cfg(), L=64)

    def test_kind_mismatch_falls_back_to_auto(self):
        # one cfg field drives both contexts; a choice for one side must
        # not break the other -- mismatches resolve to the auto pick
        name = resolve_backend("pallas_flash", _cfg(), L=64, decode=True)
        assert name in DECODE
        name = resolve_backend("pallas_flash_decode", _cfg(), L=64)
        assert name in FORWARD

    def test_auto_rules(self):
        cfg = _cfg()
        assert resolve_backend("auto", cfg, L=128) == "xla_dense"
        assert resolve_backend("auto", cfg, L=128,
                               platform="tpu") == "pallas_flash"
        assert resolve_backend("auto", cfg, L=16384) == "xla_chunked"
        assert resolve_backend(None, cfg, L=64, decode=True) == \
            "xla_dense_decode"
        assert resolve_backend("auto", cfg, L=64, decode=True,
                               platform="tpu") == "pallas_flash_decode"
        plan = _head_plan(L=64)
        assert resolve_backend("auto", cfg, L=64, plan=plan) == "xla_dense"
        assert resolve_backend("auto", cfg, L=64, plan=plan,
                               q_capacity=32) == "xla_packed"

    def test_auto_chunked_plan(self):
        from repro.core.spls_chunked import ChunkedPlan
        dummy = ChunkedPlan(*(jnp.zeros((1,)),) * 5)
        assert resolve_backend("auto", _cfg(), L=64,
                               plan=dummy) == "xla_chunked"
        assert resolve_backend("auto", _cfg(), L=64, plan=dummy,
                               platform="tpu") == "xla_chunked"


class TestForwardParityNoPlan:
    """Every forward backend == xla_dense on dense inputs."""

    @pytest.mark.parametrize("backend", [b for b in FORWARD
                                         if b != "xla_dense"])
    @pytest.mark.parametrize("causal,window,cap", [
        (True, None, None), (False, None, None), (True, 32, None),
        (False, 32, None), (True, None, 30.0), (True, 32, 30.0),
        (False, 32, 30.0),
    ])
    def test_matches_dense(self, backend, causal, window, cap):
        cfg = _cfg(causal=causal, attn_softcap=cap)
        q, k, v = _qkv()
        want = xla_dense(cfg, q, k, v, window=window)
        got = get_backend(backend)(cfg, q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=ATOL, err_msg=backend)

    @pytest.mark.parametrize("backend", FORWARD)
    def test_ragged_length(self, backend):
        """L that tiles into neither Pallas blocks nor KV chunks."""
        cfg = _cfg()
        q, k, v = _qkv(L=100)
        want = xla_dense(cfg, q, k, v)
        got = get_backend(backend)(cfg, q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=ATOL, err_msg=backend)


class TestForwardParityWithPlan:
    @pytest.mark.parametrize("causal", [True, False])
    def test_three_way_parity_column_only_plan(self, causal):
        """dense == packed == chunked == pallas under column-only sparsity."""
        cfg = _cfg(causal=causal)
        q, k, v = _qkv(seed=7)
        plan = _column_only(_head_plan(causal=causal), causal)
        outs = {b: get_backend(b)(cfg, q, k, v, plan=plan) for b in
                ("xla_dense", "xla_packed", "xla_chunked", "pallas_flash")}
        for b, o in outs.items():
            np.testing.assert_allclose(
                np.asarray(o), np.asarray(outs["xla_dense"]), atol=ATOL,
                err_msg=b)

    def test_parity_with_dead_kv_blocks(self):
        """kv_keep killing entire 128-wide K blocks (the acceptance case)."""
        cfg = _cfg(causal=True)
        B, H, L = 2, 4, 256
        q, k, v = _qkv(L=L, seed=11)
        plan = _column_only(_head_plan(L=L, causal=True), True)
        plan = _block_kill(plan, 128, 256)  # second Pallas K block fully dead
        outs = {b: get_backend(b)(cfg, q, k, v, plan=plan) for b in
                ("xla_dense", "xla_packed", "xla_chunked", "pallas_flash")}
        for b, o in outs.items():
            np.testing.assert_allclose(
                np.asarray(o), np.asarray(outs["xla_dense"]), atol=ATOL,
                err_msg=b)

    def test_full_spls_plan_simulation_vs_hardware_split(self):
        """With intra-row top-k: dense == packed and pallas == chunked."""
        cfg = _cfg(causal=True)
        q, k, v = _qkv(seed=13)
        plan = _head_plan(causal=True, k_ratio=0.2, s_threshold=0.7,
                          f_threshold=2)
        dense = xla_dense(cfg, q, k, v, plan=plan)
        packed = get_backend("xla_packed")(cfg, q, k, v, plan=plan)
        np.testing.assert_allclose(np.asarray(packed), np.asarray(dense),
                                   atol=ATOL)
        chunked = xla_chunked(cfg, q, k, v, plan=plan)
        flash = pallas_flash(cfg, q, k, v, plan=plan)
        np.testing.assert_allclose(np.asarray(flash), np.asarray(chunked),
                                   atol=ATOL)

    def test_pallas_packs_critical_rows_reduced_capacity(self):
        """Real row packing: capacity < L, rounded to whole q blocks."""
        cfg = _cfg(causal=True)
        L = 256
        q, k, v = _qkv(L=L, seed=17)
        plan = _column_only(_head_plan(L=L, causal=True, s_threshold=0.95,
                                       k_ratio=0.1), True)
        ncrit = int(plan.q_critical.sum(-1).max())
        cap = -(-ncrit // 128) * 128   # both packers see the same capacity
        assert cap < L, "want an actually reduced capacity for this test"
        flash = pallas_flash(cfg, q, k, v, plan=plan, q_capacity=cap)
        chunked = xla_chunked(cfg, q, k, v, plan=plan, q_capacity=cap)
        np.testing.assert_allclose(np.asarray(flash), np.asarray(chunked),
                                   atol=ATOL)
        # critical rows also agree with unpacked simulation numerics
        dense = xla_dense(cfg, q, k, v, plan=plan)
        crit = np.asarray(plan.q_critical[..., None] &
                          jnp.ones(flash.shape, bool))
        np.testing.assert_allclose(np.asarray(flash)[crit],
                                   np.asarray(dense)[crit], atol=ATOL)

    @pytest.mark.parametrize("causal", [True, False])
    def test_window_plus_plan_all_backends(self, causal):
        """SPLS + sliding window: every backend applies the same window
        (the XLA paths through the mask, pallas/chunked through indices)."""
        cfg = _cfg(causal=causal)
        q, k, v = _qkv(seed=19)
        plan = _column_only(_head_plan(causal=causal), causal)
        outs = {b: get_backend(b)(cfg, q, k, v, window=32, plan=plan) for b
                in ("xla_dense", "xla_packed", "xla_chunked", "pallas_flash")}
        for b, o in outs.items():
            np.testing.assert_allclose(
                np.asarray(o), np.asarray(outs["xla_dense"]), atol=ATOL,
                err_msg=b)


class TestAttentionForwardDispatch:
    """cfg.attn_backend / backend= thread through the full mixer."""

    @pytest.mark.parametrize("backend", FORWARD)
    def test_model_forward_invariant_to_backend(self, backend):
        cfg = _cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                                  cfg.vocab_size)
        want = forward(cfg, params, toks)
        got = forward(dataclasses.replace(cfg, attn_backend=backend),
                      params, toks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, err_msg=backend)

    @pytest.mark.parametrize("backend", FORWARD)
    def test_attention_forward_backend_arg(self, backend):
        cfg = _cfg()
        p = init_attention(cfg, jax.random.PRNGKey(2), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
        want = attention_forward(cfg, p, x)
        got = attention_forward(cfg, p, x, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=ATOL, err_msg=backend)

    @pytest.mark.parametrize("backend", FORWARD)
    def test_gqa_model_forward_invariant(self, backend):
        """Grouped-KV (n_kv_heads < n_heads) through every backend."""
        cfg = _cfg(n_heads=4, n_kv_heads=2)
        params = init_params(cfg, jax.random.PRNGKey(7))
        toks = jax.random.randint(jax.random.PRNGKey(8), (2, 48), 0,
                                  cfg.vocab_size)
        want = forward(cfg, params, toks)
        got = forward(dataclasses.replace(cfg, attn_backend=backend),
                      params, toks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, err_msg=backend)

    def test_chunked_ragged_kv_capacity(self):
        """Ck not a multiple of kv_chunk: internal dead-column padding
        keeps the chunk grid (and the result) intact."""
        from repro.core.sparse_exec import spls_attention_chunked
        cfg = _cfg()
        q, k, v = _qkv(L=64, seed=23)
        plan = _column_only(_head_plan(L=64, causal=True), True)
        ragged = spls_attention_chunked(q, k, v, plan, 64, 48,
                                        kv_chunk=32, causal=True)
        single = spls_attention_chunked(q, k, v, plan, 64, 48,
                                        kv_chunk=48, causal=True)
        np.testing.assert_allclose(np.asarray(ragged), np.asarray(single),
                                   atol=ATOL)

    def test_block_forward_and_decode_backend_args(self):
        """blocks.py threads attn_backend= through to the mixer."""
        from repro.models import (block_decode, block_forward, init_block,
                                  init_block_cache)
        cfg = _cfg()
        blk = cfg.period[0]
        p = init_block(cfg, blk, jax.random.PRNGKey(4), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, cfg.d_model))
        want = block_forward(cfg, blk, p, x)
        got = block_forward(cfg, blk, p, x, attn_backend="pallas_flash")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)
        cache = init_block_cache(cfg, blk, 2, 16, jnp.float32)
        x1 = jax.random.normal(jax.random.PRNGKey(6), (2, 1, cfg.d_model))
        pos = jnp.asarray([3, 7])
        want1, _ = block_decode(cfg, blk, p, x1, cache, pos)
        got1, _ = block_decode(cfg, blk, p, x1, cache, pos,
                               attn_backend="pallas_flash_decode")
        np.testing.assert_allclose(np.asarray(got1), np.asarray(want1),
                                   atol=1e-4)

    def test_spls_model_forward_all_backends_finite(self):
        spls = SPLSConfig(enabled=True, k_ratio=0.3, s_threshold=0.6,
                          f_threshold=1, window=4)
        for backend in FORWARD:
            cfg = _cfg(spls=spls, attn_backend=backend)
            params = init_params(cfg, jax.random.PRNGKey(0))
            toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                      cfg.vocab_size)
            out = forward(cfg, params, toks)
            assert np.isfinite(np.asarray(out)).all(), backend


class TestDecodeParity:
    def _decode_inputs(self, B=2, KV=2, G=2, S=96, Dh=16, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (B, KV, G, Dh))
        k = jax.random.normal(ks[1], (B, KV, S, Dh))
        v = jax.random.normal(ks[2], (B, KV, S, Dh))
        pos = jnp.asarray([S - 1, S // 3])
        return q, k, v, pos

    @pytest.mark.parametrize("window,cap", [(None, None), (24, None),
                                            (None, 30.0)])
    def test_backends_match_oracle(self, window, cap):
        from repro.kernels.ref import flash_decode_ref
        cfg = _cfg(attn_softcap=cap)
        q, k, v, pos = self._decode_inputs()
        want = flash_decode_ref(q, k, v, pos, softcap=cap, window=window)
        for b in DECODE:
            got = get_backend(b)(cfg, q, k, v, pos=pos, window=window)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=ATOL, err_msg=b)

    def test_pallas_decode_ragged_cache(self):
        """S not a multiple of the decode block -> internal padding."""
        cfg = _cfg()
        q, k, v, pos = self._decode_inputs(S=600)
        want = get_backend("xla_dense_decode")(cfg, q, k, v, pos=pos)
        got = get_backend("pallas_flash_decode")(cfg, q, k, v, pos=pos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=ATOL)

    def test_serving_engine_backend_override(self):
        """ServeConfig.attn_backend pins the engine's attention path."""
        from repro.runtime.serve import Request, ServeConfig, ServingEngine
        cfg = _cfg(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   head_dim=16, d_ff=64)
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompt = jax.random.randint(jax.random.PRNGKey(1), (8,), 0,
                                    cfg.vocab_size)
        outs = {}
        for b in (None, "pallas_flash"):
            eng = ServingEngine(cfg, params,
                                ServeConfig(n_slots=1, max_len=32,
                                            attn_backend=b))
            req = Request(rid=0, prompt=prompt, max_new_tokens=4)
            eng.submit(req)
            ticks = 0
            while not req.done and ticks < 50:
                eng.tick()
                ticks += 1
            outs[b] = req.output
        assert outs[None] == outs["pallas_flash"]
