"""Observability subsystem: registry semantics, percentile math vs the
numpy oracle, Chrome-trace pairing on real engine runs (including
preemption unwinding), the telemetry-disabled no-op path, engine.stats
back-compat, and the BENCH_serving.json report schema."""

import json

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig, BlockCfg
from repro.models import init_params
from repro.observability import (CounterDictView, MetricsRegistry,
                                 NullInstrument, RequestRecord, Telemetry,
                                 TraceRecorder, percentile, serving_report,
                                 validate_report, write_report)
from repro.serving import PagedServingEngine, Request, ServeConfig

jax.config.update("jax_platform_name", "cpu")

_PARAMS_CACHE = {}


def _cfg(**kw):
    base = dict(name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=64, period=(BlockCfg(),),
                remat=False)
    base.update(kw)
    return ArchConfig(**base)


def _params(cfg):
    key = (cfg.name, cfg.period, cfg.spls.enabled)
    if key not in _PARAMS_CACHE:
        _PARAMS_CACHE[key] = init_params(cfg, jax.random.PRNGKey(0))
    return _PARAMS_CACHE[key]


def _reqs(cfg, lens, max_new=5, seed0=0):
    return [Request(rid=i, prompt=jax.random.randint(
        jax.random.PRNGKey(seed0 + i), (lp,), 0, cfg.vocab_size),
        max_new_tokens=max_new) for i, lp in enumerate(lens)]


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_ticks=2000)
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_counter_gauge_histogram_semantics(self):
        reg = MetricsRegistry()
        c = reg.counter("a/b")
        c.inc()
        c.inc(3)
        assert c.value == 4
        assert reg.counter("a/b") is c          # create-or-return
        g = reg.gauge("g")
        g.set(5.0)
        g.set(2.0)
        g.set(3.0)
        assert g.value == 3.0 and g.high == 5.0 and g.low == 2.0
        h = reg.histogram("h")
        for v in (1.0, 2.0, 3.0):
            h.observe(v)
        assert h.count == 3 and h.mean == 2.0
        snap = reg.snapshot()
        assert snap["a/b"] == 4
        assert snap["g"]["high"] == 5.0
        assert snap["h"]["n"] == 3

    def test_kind_collision_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("x")

    def test_disabled_registry_records_nothing(self):
        reg = MetricsRegistry(enabled=False)
        inst = reg.counter("never")
        assert isinstance(inst, NullInstrument)
        inst.inc()
        reg.histogram("h").observe(1.0)
        reg.gauge("g").set(2.0)
        assert reg.snapshot() == {}
        assert reg.get("never") is None

    def test_histogram_sample_cap_is_visible(self):
        h = MetricsRegistry().histogram("h")
        h.max_samples = 10
        for v in range(25):
            h.observe(float(v))
        assert h.count == 25
        assert len(h.samples) == 10
        assert h.dropped == 15

    def test_injected_clock(self):
        t = [100.0]
        reg = MetricsRegistry(clock=lambda: t[0])
        assert reg.now() == 100.0
        t[0] = 101.5
        assert reg.now() == 101.5

    def test_counter_dict_view_back_compat(self):
        reg = MetricsRegistry()
        view = CounterDictView(reg, "s/", ("a", "b"))
        view["a"] += 1          # the legacy read-then-write idiom
        view["a"] += 2
        view["b"] = 7
        assert view["a"] == 3 and view["b"] == 7
        assert dict(view) == {"a": 3, "b": 7}
        assert reg.counter("s/a").value == 3    # lands on the typed counter
        with pytest.raises(KeyError):
            view["typo"] += 1                   # fixed key set
        with pytest.raises(TypeError):
            del view["a"]


class TestPercentile:
    @pytest.mark.parametrize("n", [1, 2, 5, 37, 100])
    def test_matches_numpy(self, n):
        rng = np.random.RandomState(n)
        vals = list(rng.rand(n) * 10)
        for p in (0.0, 1.0, 13.7, 50.0, 90.0, 99.0, 100.0):
            assert percentile(vals, p) == pytest.approx(
                np.percentile(vals, p), abs=1e-12)

    def test_empty_is_nan(self):
        assert np.isnan(percentile([], 50.0))

    def test_histogram_summary_vs_numpy(self):
        h = MetricsRegistry().histogram("h")
        rng = np.random.RandomState(0)
        vals = rng.rand(200)
        for v in vals:
            h.observe(float(v))
        assert h.percentile(50.0) == pytest.approx(np.percentile(vals, 50))
        assert h.percentile(99.0) == pytest.approx(np.percentile(vals, 99))
        assert h.mean == pytest.approx(vals.mean())


# ---------------------------------------------------------------------------
# trace recorder
# ---------------------------------------------------------------------------

class TestTraceRecorder:
    def test_paired_events_validate(self):
        tr = TraceRecorder()
        tr.begin("request", 0.0, 1)
        tr.begin("prefill", 0.1, 1)
        tr.end("prefill", 0.2, 1)
        tr.instant("first_token", 0.2, 1)
        tr.end("request", 0.3, 1)
        tr.validate()
        chrome = tr.to_chrome_trace()
        assert set(chrome) == {"traceEvents", "displayTimeUnit"}
        assert chrome["traceEvents"][0]["ts"] == 0.0
        assert chrome["traceEvents"][1]["ts"] == pytest.approx(1e5)
        assert [e["ph"] for e in chrome["traceEvents"]] == \
            ["B", "B", "E", "i", "E"]

    def test_validate_rejects_unclosed_and_misnested(self):
        tr = TraceRecorder()
        tr.begin("a", 0.0, 1)
        with pytest.raises(ValueError, match="unclosed"):
            tr.validate()
        tr2 = TraceRecorder()
        tr2.begin("a", 0.0, 1)
        tr2.begin("b", 0.1, 1)
        tr2.events.append({"ph": "E", "name": "a", "ts": 0.2, "pid": 1,
                           "tid": 1})
        with pytest.raises(ValueError, match="nesting"):
            tr2.validate()

    def test_validate_rejects_time_regression(self):
        tr = TraceRecorder()
        tr.begin("a", 1.0, 1)
        tr.end("a", 0.5, 1)
        with pytest.raises(ValueError, match="regress"):
            tr.validate()

    def test_disabled_records_nothing(self):
        tr = TraceRecorder(enabled=False)
        tr.begin("a", 0.0, 1)
        tr.instant("i", 0.1, 1)
        tr.end("a", 0.2, 1)
        assert tr.events == []
        tr.validate()

    def test_max_events_counts_drops(self):
        tr = TraceRecorder(max_events=3)
        for i in range(5):
            tr.instant("x", float(i), 1)
        assert len(tr.events) == 3 and tr.dropped == 2

    def test_open_span_stack_tracks_nesting(self):
        tr = TraceRecorder()
        tr.begin("request", 0.0, 3)
        tr.begin("prefill", 0.1, 3)
        assert tr.open_spans(3) == ["request", "prefill"]
        tr.end("prefill", 0.2, 3)
        assert tr.open_spans(3) == ["request"]


# ---------------------------------------------------------------------------
# telemetry facade (fake clock)
# ---------------------------------------------------------------------------

class TestTelemetryLifecycle:
    def _tel(self):
        t = {"now": 0.0}

        def clock():
            return t["now"]

        return Telemetry(clock=clock), t

    def test_ttft_tpot_from_injected_clock(self):
        tel, t = self._tel()
        tel.request_submitted(0, prompt_len=8)
        t["now"] = 1.0
        tel.request_admitted(0)
        t["now"] = 2.0
        tel.first_token(0)
        for ts in (2.5, 3.0, 3.5):
            t["now"] = ts
            tel.tokens_decoded([0])
        tel.request_retired(0)
        rec = tel.requests[0]
        assert rec.ttft_s == 2.0            # submit -> first token
        assert rec.n_tokens == 4
        assert rec.tpot_s == pytest.approx(0.5)
        assert rec.outcome == "retired"
        tel.trace.validate()

    def test_preemption_unwinds_open_spans(self):
        tel, t = self._tel()
        tel.request_submitted(0, prompt_len=8)
        tel.request_admitted(0)
        tel.span_begin("prefill_chunk", rid=0)
        t["now"] = 1.0
        tel.request_preempted(0)            # struck mid-phase
        t["now"] = 2.0
        tel.request_admitted(0)
        tel.request_retired(0)
        tel.trace.validate()                # B/E pairing survived
        assert tel.requests[0].n_preempts == 1
        assert tel.metrics.counter("requests/requeues").value == 1

    def test_abort_closes_request_span(self):
        tel, t = self._tel()
        tel.request_submitted(0, prompt_len=8)
        tel.request_admitted(0)
        tel.span_begin("full_prefill", rid=0)
        tel.request_aborted(0)
        tel.trace.validate()
        assert tel.requests[0].outcome == "aborted"

    def test_disabled_facade_records_nothing(self):
        tel = Telemetry(enabled=False)
        tel.request_submitted(0, prompt_len=8)
        tel.request_admitted(0)
        tel.first_token(0)
        tel.tokens_decoded([0])
        tel.request_retired(0)
        tel.span_begin("x")
        tel.span_end("x")
        assert tel.requests == {}
        assert tel.metrics.snapshot() == {}
        assert tel.trace.events == []

    def test_record_properties_incomplete(self):
        rec = RequestRecord(rid=0, prompt_len=4, submit_ts=0.0)
        assert rec.ttft_s is None and rec.tpot_s is None


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

class TestEngineTelemetry:
    def test_trace_valid_and_stats_back_compat(self, tmp_path):
        cfg = _cfg()
        params = _params(cfg)
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=48, page_size=4, prefill_chunk=8,
            attn_backend="xla_paged_decode"))
        _drain(eng, _reqs(cfg, [30, 7, 25]))
        tel = eng.telemetry
        tel.trace.validate()
        # legacy stats counters live (typed instruments underneath)
        assert eng.stats["prefill_chunks"] >= 4
        assert eng.stats["admitted"] == 3
        assert eng.stats["retired"] == 3
        assert tel.core.counter("sched/prefill_chunks").value == \
            eng.stats["prefill_chunks"]
        # every request retired with tokens and a ttft
        assert len(tel.requests) == 3
        for rec in tel.requests.values():
            assert rec.outcome == "retired"
            assert rec.n_tokens == 5
            assert rec.ttft_s is not None and rec.ttft_s >= 0
        # the trace round-trips as Chrome JSON
        path = tmp_path / "trace.json"
        tel.trace.write(str(path))
        chrome = json.loads(path.read_text())
        assert {e["ph"] for e in chrome["traceEvents"]} <= {"B", "E", "i"}
        names = {e["name"] for e in chrome["traceEvents"]}
        assert {"request", "queued", "prefill_chunk", "decode_tick",
                "first_token"} <= names

    def test_preemption_trace_stays_paired(self):
        cfg = _cfg()
        params = _params(cfg)
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=3, max_len=32, page_size=4, n_pages=9,
            attn_backend="xla_paged_decode"))
        _drain(eng, _reqs(cfg, [12, 12, 12], max_new=6))
        assert eng.stats["preemptions"] > 0
        eng.telemetry.trace.validate()
        report = serving_report(eng)
        assert report["requests"]["preemptions"] > 0
        assert report["requests"]["preemption_rate"] > 0

    def test_telemetry_off_is_bitwise_identical_and_silent(self):
        cfg = _cfg()
        params = _params(cfg)

        def run(telemetry):
            eng = PagedServingEngine(cfg, params, ServeConfig(
                n_slots=2, max_len=48, page_size=4, prefill_chunk=8,
                attn_backend="xla_paged_decode", telemetry=telemetry))
            return _drain(eng, _reqs(cfg, [30, 7, 25])), eng

        on, eng_on = run(True)
        off, eng_off = run(False)
        assert on == off                      # greedy outputs bit-for-bit
        tel = eng_off.telemetry
        assert tel.trace.events == []
        assert tel.metrics.snapshot() == {}
        assert tel.requests == {}
        # back-compat stats stay live either way (always-on core)
        assert eng_off.stats["retired"] == eng_on.stats["retired"] == 3
        assert eng_off.stats["prefill_chunks"] == \
            eng_on.stats["prefill_chunks"]

    def test_pool_gauges_and_guard_counter(self):
        from repro.serving import PagePool

        cfg = _cfg()
        params = _params(cfg)
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=48, page_size=4, prefill_chunk=8,
            attn_backend="xla_paged_decode"))
        _drain(eng, _reqs(cfg, [30, 7]))
        m = eng.telemetry.metrics
        gauge = m.get("pool/pages_in_use")
        assert gauge is not None
        assert gauge.high >= eng.stats["peak_pages"] - 1  # tick-sampled
        assert gauge.value == 0                           # drained
        assert m.get("pool/guard_trips").value == 0
        assert eng.stats["guard_trips"] == 0
        # the guard itself: a double free raises AND counts
        pool = PagePool(6, 4)
        pages = pool.alloc(2)
        pool.free(pages)
        with pytest.raises(ValueError, match="free"):
            pool.free(pages)
        assert pool.guard_trips == 1


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

class TestReport:
    def _engine_report(self, tmp_path):
        cfg = _cfg()
        params = _params(cfg)
        eng = PagedServingEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=48, page_size=4, prefill_chunk=8,
            attn_backend="xla_paged_decode"))
        _drain(eng, _reqs(cfg, [30, 7, 25]))
        return serving_report(eng, wall_s=1.0)

    def test_schema_valid_and_round_trips(self, tmp_path):
        report = self._engine_report(tmp_path)
        validate_report(report)
        assert report["schema_version"] == 1
        assert report["requests"]["retired"] == 3
        assert report["latency"]["ttft_ms"]["n"] == 3
        assert report["latency"]["tpot_ms"]["p50"] > 0
        assert report["throughput"]["tokens"] == 15
        assert report["throughput"]["goodput_tok_s"] == \
            report["throughput"]["tok_s"]     # nothing aborted
        for c in ("qkv", "kv", "attn", "ffn"):
            assert f"flops_saved_{c}_pct" in report["sparsity"]
        path = tmp_path / "BENCH_serving.json"
        write_report(str(path), report)
        validate_report(json.loads(path.read_text()))

    def test_validator_names_all_problems(self, tmp_path):
        report = self._engine_report(tmp_path)
        del report["latency"]["ttft_ms"]
        report["schema_version"] = 99
        with pytest.raises(ValueError) as ei:
            validate_report(report)
        msg = str(ei.value)
        assert "ttft_ms" in msg and "schema_version 99" in msg

    def test_require_nonzero_flops(self, tmp_path):
        report = self._engine_report(tmp_path)   # dense compute: all 0.0
        validate_report(report)                  # fine without the flag
        with pytest.raises(ValueError, match="flops_saved_qkv_pct"):
            validate_report(report, require_nonzero_flops=True)

    def test_cli_validates(self, tmp_path, capsys):
        from repro.observability.report import main

        report = self._engine_report(tmp_path)
        path = tmp_path / "r.json"
        write_report(str(path), report)
        assert main([str(path)]) == 0
        assert main([str(path), "--require-nonzero-flops"]) == 1
