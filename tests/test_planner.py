"""Unified SPLS planner (repro.core.planner): driver-unification parity,
horizon-finalized column votes (None == end-of-prefill bit-for-bit, finite
horizons monotone), the int8 predictor-cache round-trip, packed K/V
projection parity, and whole-prompt packed routing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.planner import (PlanContext, horizon_update_live,
                                own_column_keep, pack_within_capacity,
                                votes_from_kv_any)
from repro.core.spls import SPLSConfig
from repro.core.spls_chunked import chunked_plan_scan
from repro.core.topk import topk_count
from repro.models import init_params
from repro.serving import (PagedServingEngine, Request, ServeConfig,
                           ServingEngine, init_pred_cache, spls_token_votes)
from repro.serving.pager import keep_from_votes

jax.config.update("jax_platform_name", "cpu")

_PARAMS_CACHE = {}


def _cfg(**kw):
    base = dict(name="tiny-planner", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
                period=(BlockCfg(),), remat=False)
    base.update(kw)
    return ArchConfig(**base)


def _spls_cfg(**kw):
    spls = dict(enabled=True, k_ratio=0.12, s_threshold=0.6, f_threshold=2,
                window=4, causal=True)
    spls.update(kw.pop("spls_kw", {}))
    return _cfg(spls=SPLSConfig(**spls), **kw)


def _params(cfg):
    key = (cfg.name, cfg.period, cfg.spls.enabled, cfg.spls.k_ratio)
    if key not in _PARAMS_CACHE:
        _PARAMS_CACHE[key] = init_params(cfg, jax.random.PRNGKey(0))
    return _PARAMS_CACHE[key]


def _blk0(cfg, params):
    return jax.tree.map(lambda a: a[0], params["periods"][0])


def _reqs(cfg, lens, max_new=4, seed0=10):
    return [Request(rid=i, prompt=jax.random.randint(
        jax.random.PRNGKey(seed0 + i), (lp,), 0, cfg.vocab_size),
        max_new_tokens=max_new) for i, lp in enumerate(lens)]


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_ticks=3000)
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


# ---------------------------------------------------------------------------
# driver unification: identical plans from identical predicted heads
# ---------------------------------------------------------------------------

class TestDriverParity:
    def _heads(self, B=1, KV=2, G=2, L=32, Dh=16, seed=0):
        qh = jax.random.normal(jax.random.PRNGKey(seed), (B, KV, G, L, Dh))
        kh = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, KV, L, Dh))
        return qh, kh

    def test_three_drivers_identical_plans(self):
        """One-shot (simulation), lax.scan (progressive), and streaming
        chunk-by-chunk (serving) emit identical plans on identical
        predicted heads -- the planner-unification invariant."""
        L, C = 32, 8
        cfg = _spls_cfg()
        ctx = PlanContext.for_config(cfg, mode="structured")
        qh, kh = self._heads(L=L)
        k = topk_count(L, cfg.spls.k_ratio)

        one = ctx.plan_block(qh, kh, k=k, row0=0, n_valid_rows=L, n_cols=L)

        scan = chunked_plan_scan(
            qh, kh, k_ratio=cfg.spls.k_ratio,
            s_threshold=cfg.spls.s_threshold, window=cfg.spls.window,
            f_threshold=cfg.spls.f_threshold, row_block=C)
        np.testing.assert_array_equal(np.asarray(scan.q_critical),
                                      np.asarray(one.q_critical))
        np.testing.assert_array_equal(np.asarray(scan.q_leader),
                                      np.asarray(one.q_leader))
        np.testing.assert_array_equal(np.asarray(scan.kv_keep),
                                      np.asarray(one.kv_any))
        np.testing.assert_array_equal(np.asarray(scan.ffn_critical),
                                      np.asarray(one.ffn_critical))

        # streaming: grow the column buffer chunk by chunk, votes OR'd
        acc = None
        got_crit, got_lead = [], []
        for c0 in range(0, L, C):
            seen = c0 + C
            kh_buf = jnp.concatenate(
                [kh[:, :, :seen], jnp.full((1, 2, L - seen, 16), 7.0)],
                axis=2)  # garbage past the seen columns
            pb = ctx.plan_block(qh[..., c0:c0 + C, :], kh_buf, k=k, row0=c0,
                                n_valid_rows=C, n_cols=seen)
            acc = pb.kv_any if acc is None else acc | pb.kv_any
            got_crit.append(pb.q_critical)
            got_lead.append(pb.q_leader)
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(a) for a in got_crit], -1),
            np.asarray(one.q_critical))
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(a) for a in got_lead], -1),
            np.asarray(one.q_leader))
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(one.kv_any))

    def test_progressive_assembly_matches_vote_iter(self):
        """plan_progressive's kv_keep equals the OR of the votes-only
        block iterator -- full plans and the serving vote path share one
        block source."""
        cfg = _spls_cfg()
        params = _params(cfg)
        blk0 = _blk0(cfg, params)
        xn = jax.random.normal(jax.random.PRNGKey(3), (1, 24, cfg.d_model))
        ctx = PlanContext.for_config(cfg)
        plan = ctx.plan_progressive(blk0["attn"], xn, row_block=8)
        acc = None
        for v in ctx.iter_blocks(blk0["attn"], xn, row_block=8,
                                 votes_only=True):
            acc = v if acc is None else acc | v
        np.testing.assert_array_equal(np.asarray(plan.kv_keep),
                                      np.asarray(acc))

    def test_col_live_kills_columns(self):
        """Dead columns (col_live False) can neither win top-k mask bits
        nor receive keep votes."""
        cfg = _spls_cfg()
        ctx = PlanContext.for_config(cfg, mode="structured")
        qh, kh = self._heads(L=16)
        live = jnp.ones((16,), bool).at[5].set(False).at[11].set(False)
        pb = ctx.plan_block(qh, kh, k=jnp.int32(4), row0=0, n_valid_rows=16,
                            n_cols=16, col_live=live)
        m = np.asarray(pb.mask)
        assert not m[..., 5].any() and not m[..., 11].any()
        v = np.asarray(pb.kv_any)
        assert not v[..., 5].any() and not v[..., 11].any()


# ---------------------------------------------------------------------------
# int8 predictor-cache codes
# ---------------------------------------------------------------------------

class TestPredCacheCodes:
    @pytest.mark.parametrize("method", ["hlog", "hlog_bitlevel", "pot",
                                        "none"])
    def test_roundtrip_bitwise(self, method):
        """encode -> int8 codes + scale -> decode reproduces the
        dequantized predicted K bit-for-bit for every quantizer."""
        from repro.core.predict import predict_qk
        cfg = _spls_cfg(spls_kw=dict(quant_method=method))
        params = _params(_spls_cfg())  # weights independent of method
        blk0 = _blk0(cfg, params)
        xn = jax.random.normal(jax.random.PRNGKey(5), (1, 16, cfg.d_model))
        ctx = PlanContext.for_config(cfg, mode="structured")
        qh, codes, scale = ctx.encode_pred_qk(blk0["attn"], xn)
        assert codes.dtype == jnp.int8
        dec = ctx.decode_pred_k(codes, scale)
        D, KV, Dh = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
        wq = blk0["attn"]["wq"].reshape(D, -1)
        wk = blk0["attn"]["wk"].reshape(D, KV * Dh)
        _, kp = predict_qk(xn, wq, wk, method, cfg.spls.quant_bits,
                           act_axis=-1)
        kp_h = kp.reshape(16, KV, Dh).transpose(1, 0, 2)
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(kp_h))

    def test_roundtrip_bitwise_bf16(self):
        """Under bfloat16 compute the decode must multiply in bf16 (the
        dtype the old float cache stored): levels and the widened scale
        round-trip exactly, so decode(dtype=bf16) equals the bf16
        predict_qk output bit for bit (an f32 multiply would differ in
        the last ulp and flip marginal top-k columns)."""
        from repro.core.predict import predict_qk
        cfg = _spls_cfg()
        params = _params(cfg)
        blk0 = jax.tree.map(lambda a: a.astype(jnp.bfloat16),
                            _blk0(cfg, params))
        xn = jax.random.normal(jax.random.PRNGKey(6),
                               (1, 16, cfg.d_model)).astype(jnp.bfloat16)
        ctx = PlanContext.for_config(cfg, mode="structured")
        _, codes, scale = ctx.encode_pred_qk(blk0["attn"], xn)
        dec = ctx.decode_pred_k(codes, scale, dtype=jnp.bfloat16)
        assert dec.dtype == jnp.bfloat16
        D, KV, Dh = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
        wq = blk0["attn"]["wq"].reshape(D, -1)
        wk = blk0["attn"]["wk"].reshape(D, KV * Dh)
        _, kp = predict_qk(xn, wq, wk, cfg.spls.quant_method,
                           cfg.spls.quant_bits, act_axis=-1)
        kp_h = kp.reshape(16, KV, Dh).transpose(1, 0, 2)
        np.testing.assert_array_equal(
            np.asarray(dec, np.float32), np.asarray(kp_h, np.float32))

    def test_pool_bytes_reduced(self):
        """The paged predictor cache charges int8 codes + one float32
        scale per slot -- strictly below the old float32-value layout."""
        cfg = _spls_cfg()
        pred = init_pred_cache(cfg, n_pages=8, page_size=4)
        got = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(pred))
        KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
        n_blocks = len(cfg.period)
        old = n_blocks * cfg.n_periods * KV * 8 * 4 * Dh * 4  # float32
        assert got < old / 2, (got, old)
        assert pred[0].codes.dtype == jnp.int8
        assert pred[0].scale.dtype == jnp.float32

    def test_wide_quant_bits_rejected(self):
        cfg = _spls_cfg(spls_kw=dict(quant_bits=16))
        with pytest.raises(ValueError, match="quant_bits"):
            init_pred_cache(cfg, n_pages=4, page_size=4)


# ---------------------------------------------------------------------------
# horizon-finalized column votes
# ---------------------------------------------------------------------------

class _KeepRecorder(PagedServingEngine):
    """Records each sequence's final keep set at compaction time."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.kept = {}

    def _finish_chunk_prune(self, st):
        lp = st.prompt_len
        votes = st.head_votes.sum(axis=0).astype(np.int32)
        keep = keep_from_votes(votes[:lp], self.cfg.n_heads,
                               self.scfg.spls_prune_vote)
        if st.live is not None:
            keep = keep & st.live[:lp]
        self.kept[st.req.rid] = keep.copy()
        super()._finish_chunk_prune(st)


class _VoteRecorder(PagedServingEngine):
    """Records each sequence's accumulated head votes at compaction."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.votes = {}

    def _finish_chunk_prune(self, st):
        self.votes[st.req.rid] = st.head_votes.copy()
        super()._finish_chunk_prune(st)


class TestVoteHorizon:
    def _run(self, cfg, params, engine_cls=PagedServingEngine, lens=(30, 25),
             chunk=8, max_new=4, **scfg_kw):
        scfg = ServeConfig(n_slots=2, max_len=64, page_size=4,
                           prefill_chunk=chunk,
                           attn_backend="xla_paged_decode", **scfg_kw)
        eng = engine_cls(cfg, params, scfg)
        outs = _drain(eng, _reqs(cfg, lens, max_new=max_new))
        return outs, eng

    @pytest.mark.parametrize("chunk,gqa,swa", [(8, False, False),
                                               (16, False, False),
                                               (8, True, False),
                                               (8, False, True)])
    def test_none_streaming_votes_equal_end_of_prefill(self, chunk, gqa,
                                                       swa):
        """vote_horizon=None: the chunk-streamed vote accumulator equals
        the whole-prompt planner vote bit-for-bit, across chunk sizes,
        GQA groupings, and sliding-window blocks."""
        kw = {}
        if gqa:
            kw = dict(n_heads=4, n_kv_heads=1, name="tiny-planner-gqa")
        if swa:
            kw = dict(period=(BlockCfg(window=6),), name="tiny-planner-swa")
        cfg = _spls_cfg(**kw)
        params = _params(cfg)
        lens = (30, 25)
        _, eng = self._run(cfg, params, engine_cls=_VoteRecorder, lens=lens,
                           chunk=chunk, vote_horizon=None)
        for rid, lp in enumerate(lens):
            want = np.asarray(spls_token_votes(
                cfg, params, jnp.asarray(_reqs(cfg, lens)[rid].prompt)))
            got = eng.votes[rid].sum(axis=0).astype(np.int32)[:lp]
            np.testing.assert_array_equal(got, want)

    def test_none_is_default_engine_bitwise(self):
        """Explicit vote_horizon=None greedy outputs are bit-for-bit the
        default (PR-4) engine's, dense and packed compute alike."""
        cfg = _spls_cfg()
        params = _params(cfg)
        for cb, kw in (("dense", {}), ("packed_xla",
                                       dict(capacity_buckets=(8,)))):
            base, _ = self._run(cfg, params, compute_backend=cb, **kw)
            expl, _ = self._run(cfg, params, compute_backend=cb,
                                vote_horizon=None, **kw)
            assert base == expl, cb

    def test_full_vote_horizon_one_is_lossless(self):
        """k_ratio=1.0 makes every column win the cross-head vote inside
        its own chunk, so vote_horizon=1 (packed K/V projection included)
        must reproduce vote_horizon=None bit-for-bit -- this pins the
        packed_project_kv numerics end to end."""
        cfg = _spls_cfg(spls_kw=dict(k_ratio=1.0), name="tiny-planner-k1")
        params = _params(cfg)
        a, _ = self._run(cfg, params, compute_backend="packed_xla",
                         capacity_buckets=(8,))
        b, eng = self._run(cfg, params, compute_backend="packed_xla",
                           capacity_buckets=(8,), vote_horizon=1)
        assert a == b
        assert eng.stats["capacity_kv"]["observations"] > 0

    def test_horizon_monotone_kept_columns(self):
        """Larger horizon => superset of kept columns (votes are monotone;
        a longer probation can only rescue columns)."""
        cfg = _spls_cfg(spls_kw=dict(s_threshold=0.9))
        params = _params(cfg)
        kept = {}
        for h in (1, 2, 4, None):
            _, eng = self._run(cfg, params, engine_cls=_KeepRecorder,
                               lens=(30, 30, 25), chunk=8,
                               compute_backend="packed_xla",
                               capacity_buckets=(8,), vote_horizon=h)
            kept[h] = eng.kept
        for a, b in ((1, 2), (2, 4), (4, None)):
            for rid in kept[a]:
                assert (~kept[a][rid] | kept[b][rid]).all(), (a, b, rid)

    def test_finite_horizon_prunes_and_drains(self):
        """A finite horizon with sparse votes finalizes columns early,
        the engine still drains, and the final keep honors liveness."""
        cfg = _spls_cfg(spls_kw=dict(s_threshold=0.9))
        params = _params(cfg)
        outs, eng = self._run(cfg, params, engine_cls=_KeepRecorder,
                              lens=(30, 25), vote_horizon=2)
        assert all(len(o) == 4 for o in outs)
        assert eng.stats["retired"] == 2

    def test_horizon_requires_spls_and_prune(self):
        cfg = _cfg()
        with pytest.raises(ValueError, match="vote_horizon"):
            PagedServingEngine(cfg, _params(cfg), ServeConfig(
                n_slots=2, max_len=64, page_size=4, vote_horizon=1))
        cfg = _spls_cfg()
        with pytest.raises(ValueError, match="vote_horizon"):
            PagedServingEngine(cfg, _params(cfg), ServeConfig(
                n_slots=2, max_len=64, page_size=4, vote_horizon=0))

    def test_host_mirror_matches_device_decision(self):
        """horizon_update_live's kv_capacity branch reproduces exactly the
        own_column_keep + pack_within_capacity decision the device
        materialized (anchor reservation included)."""
        rng = np.random.RandomState(0)
        CS, S, Ckv, last = 8, 32, 4, 29
        for start in (0, 8, 24):
            kv_any = rng.rand(1, 2, 2, S) < 0.3
            need = 2
            dev_keep = np.asarray(own_column_keep(
                jnp.asarray(kv_any), start=jnp.int32(start), chunk=CS,
                valid=jnp.int32(CS), last_keep=jnp.int32(last),
                vote_need=need))
            anchor = start + np.arange(CS) == last
            dev_written = np.asarray(pack_within_capacity(
                jnp.asarray(dev_keep), Ckv, anchor=jnp.asarray(anchor)))
            live = np.ones((S,), bool)
            counts = kv_any.reshape(-1, S).sum(axis=0).astype(np.int32)
            host = horizon_update_live(
                live, counts, start=start, valid=CS, chunk=CS, horizon=1,
                last_keep=last, vote_need=need, kv_capacity=Ckv)
            np.testing.assert_array_equal(host[start:start + CS],
                                          dev_written)

    def test_anchor_survives_capacity_overflow(self):
        """The decode anchor (highest index of its chunk) keeps its
        reserved projection slot even when the vote-surviving count
        overflows kv_capacity -- plain pack order would drop it first."""
        keep = jnp.ones((8,), bool)        # every column vote-kept
        anchor = jnp.arange(8) == 7        # anchor at the chunk's end
        w = np.asarray(pack_within_capacity(keep, 3, anchor=anchor))
        assert w[7]                        # reserved despite overflow
        assert w.sum() == 3                # capacity still respected
        np.testing.assert_array_equal(w[:7],
                                      [True, True, False, False, False,
                                       False, False])
        # without an anchor present the cap is the plain prefix rule
        w2 = np.asarray(pack_within_capacity(keep, 3,
                                             anchor=jnp.zeros(8, bool)))
        np.testing.assert_array_equal(
            w2, np.asarray(pack_within_capacity(keep, 3)))

    def test_anchor_survives_overflow_in_engine(self):
        """Engine-level regression: a pinned tiny kv capacity forces
        overflow on every chunk incl. the final one; the last prompt
        token's column must survive to anchor decode, and the engine must
        drain."""
        cfg = _spls_cfg(spls_kw=dict(k_ratio=1.0), name="tiny-planner-ovf")
        params = _params(cfg)
        scfg = ServeConfig(n_slots=2, max_len=64, page_size=4,
                           prefill_chunk=8,
                           attn_backend="xla_paged_decode",
                           compute_backend="packed_xla", vote_horizon=1)
        eng = _KeepRecorder(cfg, params, scfg)
        eng._cap_kv.capacity = lambda: 2   # force overflow every chunk
        outs = _drain(eng, _reqs(cfg, (30, 25)))
        assert all(len(o) == 4 for o in outs)
        assert eng.stats["capacity_kv"]["overflows"] > 0
        for rid in eng.kept:
            assert eng.kept[rid][-1]       # decode anchor kept


# ---------------------------------------------------------------------------
# packed K/V projection + whole-prompt routing
# ---------------------------------------------------------------------------

class TestPackedKV:
    @pytest.mark.parametrize("backend", ["packed_xla", "packed_pallas"])
    def test_packed_project_kv_bitwise(self, backend):
        """packed_project_kv slot c == row perm[c] of the dense
        project_kv output, bit for bit (XLA and Pallas-interpret)."""
        from repro.models.attention import project_kv
        cfg = _spls_cfg()
        params = _params(cfg)
        blk0 = _blk0(cfg, params)
        p = jax.tree.map(lambda a: a.astype(jnp.float32), blk0["attn"])
        xn = jax.random.normal(jax.random.PRNGKey(7), (1, 16, cfg.d_model))
        positions = jnp.arange(16)[None, :]
        kd, vd = project_kv(cfg, p, xn, positions, "structured")
        perm = jnp.asarray([3, 0, 7, 12, 12, 5], jnp.int32)
        kp, vp = project_kv(cfg, p, xn, positions, "structured", perm=perm,
                            compute_backend=backend)
        np.testing.assert_array_equal(np.asarray(kp),
                                      np.asarray(kd[:, :, perm]))
        np.testing.assert_array_equal(np.asarray(vp),
                                      np.asarray(vd[:, :, perm]))

    def test_whole_prompt_packed_routing(self):
        """Short prompts (<= one chunk) under a packed compute backend
        route through the chunk path: packed savings accrue where the
        dense full-prefill path used to report zero, and greedy outputs
        still match the dense-compute engine."""
        cfg = _spls_cfg(spls_kw=dict(s_threshold=0.95, window=8),
                        name="tiny-planner-wp")
        params = _params(cfg)
        lens = (8, 6, 8)  # all <= prefill_chunk
        scfg = dict(n_slots=3, max_len=64, page_size=4, prefill_chunk=8,
                    attn_backend="xla_paged_decode")
        dense = PagedServingEngine(cfg, params, ServeConfig(
            compute_backend="dense", **scfg))
        d_out = _drain(dense, _reqs(cfg, lens))
        packed = PagedServingEngine(cfg, params, ServeConfig(
            compute_backend="packed_xla", capacity_buckets=(8,), **scfg))
        assert packed.sched.use_chunks(6)
        p_out = _drain(packed, _reqs(cfg, lens))
        assert p_out == d_out
        # adaptive buckets: short prompts now accrue packed savings where
        # the dense full-prefill path used to report zero (run a warmup
        # batch so the controllers' EMAs leave the conservative first
        # pick, then measure)
        adaptive = PagedServingEngine(cfg, params, ServeConfig(
            compute_backend="packed_xla", capacity_buckets=(2, 4, 6, 8),
            capacity_margin=1.0, **scfg))
        _drain(adaptive, _reqs(cfg, lens, seed0=50))
        _drain(adaptive, _reqs(cfg, lens))
        assert adaptive.stats["flops_saved_pct"]["ffn"] > 0.0

    def test_double_buffered_gather_multi_tile(self):
        """The double-buffered per-row DMA gather stays bitwise equal to
        the XLA oracle across multiple row tiles (interpret mode)."""
        from repro.kernels.gathered_matmul import gathered_matmul
        x = jax.random.normal(jax.random.PRNGKey(11), (100, 32))
        w = jax.random.normal(jax.random.PRNGKey(12), (32, 48))
        perm = jax.random.randint(jax.random.PRNGKey(13), (70,), 0, 100)
        out = gathered_matmul(x, w, perm, bm=16, interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(x[perm] @ w))
