"""Quickstart: the SPLS mechanism on one attention layer, end to end.

Runs the full paper pipeline on CPU in a few seconds:
  HLog prediction -> PAM -> top-k -> SPA -> local similarity -> MFI
and prints the sparsity + exact FLOPs reduction the accelerator would
realise, then executes attention both dense and SPLS-sparse and reports
the output deviation.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (SPLSConfig, build_plan, plan_stats, reduction_report,
                        spls_attention)


def main():
    B, L, D, H, d_ff = 2, 128, 256, 8, 1024
    key = jax.random.PRNGKey(0)

    # language-like activations: neighboring tokens correlate (the paper's
    # premise -- local similarity comes from local semantics)
    eps = jax.random.normal(key, (B, L, D))
    xs = [eps[:, 0]]
    for t in range(1, L):
        xs.append(0.9 * xs[-1] + jnp.sqrt(1 - 0.81) * eps[:, t])
    x = jnp.stack(xs, axis=1)

    wq = jax.random.normal(jax.random.PRNGKey(1), (D, D)) * D ** -0.5
    wk = jax.random.normal(jax.random.PRNGKey(2), (D, D)) * D ** -0.5

    cfg = SPLSConfig(enabled=True, k_ratio=0.12, s_threshold=0.6,
                     f_threshold=5, window=8, causal=False)
    plan = build_plan(x, wq, wk, H, cfg)

    print("== SPLS plan (HLog -> top-k -> local similarity -> MFI) ==")
    for k, v in plan_stats(plan).items():
        print(f"  {k:22s} {float(v):.3f}")
    print("== exact FLOPs reduction (Fig. 15 accounting) ==")
    for k, v in reduction_report(plan, D, d_ff, causal=False).items():
        print(f"  {k:22s} {float(v):.3f}")

    # execute attention under the plan vs dense -- q/k/v must come from the
    # same activations the plan was predicted from (as in the real model)
    Dh = D // H
    wv = jax.random.normal(jax.random.PRNGKey(3), (D, D)) * D ** -0.5
    split = lambda t: t.reshape(B, L, H, Dh).swapaxes(1, 2)
    q, kk, v = split(x @ wq), split(x @ wk), split(x @ wv)
    dense = jax.nn.softmax(
        jnp.einsum("bhqd,bhkd->bhqk", q, kk) * Dh ** -0.5, -1)
    dense = jnp.einsum("bhqk,bhkd->bhqd", dense, v)
    sparse = spls_attention(q, kk, v, plan)
    rel = float(jnp.linalg.norm(sparse - dense) / jnp.linalg.norm(dense))
    print(f"== sparse vs dense attention: relative L2 deviation {rel:.3f} ==")
    print("   (bounded deviation at >50% compute removed is the trade the "
          "paper tunes with (k, s, f))")


if __name__ == "__main__":
    main()
