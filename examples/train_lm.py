"""End-to-end driver: train a ~100M-parameter causal LM for a few hundred
steps on the synthetic pipeline, with checkpointing and an injected node
failure that the trainer heals from.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--spls]

On CPU this takes a few minutes; the same Trainer + mesh-aware step scale
to the production mesh (see repro/launch/dryrun.py for the 512-chip proof).
"""

import argparse
import dataclasses
import json
import tempfile

import jax

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.spls import SPLSConfig
from repro.data.pipeline import DataConfig
from repro.runtime import FailureSimulator, Trainer, TrainerConfig


def build_cfg(spls: bool) -> ArchConfig:
    """~100M params: 8 layers x d_model 768 (GQA 12/4) x d_ff 2304."""
    return ArchConfig(
        name="lm-100m", n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=2304, vocab_size=32000,
        period=(BlockCfg(mixer="attn"),), remat=False,
        spls=SPLSConfig(enabled=spls, k_ratio=0.2, s_threshold=0.5,
                        f_threshold=4, window=8, causal=True))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--spls", action="store_true")
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    cfg = build_cfg(args.spls)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  spls={args.spls}")

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.batch, seed=0)
    with tempfile.TemporaryDirectory() as ckdir:
        sim = (FailureSimulator(fail_at_steps=(args.steps // 2,))
               if args.inject_failure else None)
        t = Trainer(cfg, TrainerConfig(
            total_steps=args.steps, ckpt_dir=ckdir, ckpt_every=50,
            log_every=25, peak_lr=3e-4, warmup_steps=50, n_micro=2),
            data, failure_sim=sim)
        out = t.run()
    print(json.dumps(out["metrics"], indent=1))
    first, last = out["metrics"][0], out["metrics"][-1]
    print(f"loss {first['loss']:.3f} -> {last['loss']:.3f}   "
          f"accuracy {first['accuracy']:.3f} -> {last['accuracy']:.3f}")
    if args.inject_failure:
        print("(one node failure was injected mid-run and healed from the "
              "last checkpoint)")


if __name__ == "__main__":
    main()
