"""Serve a small model with batched requests through the continuous-
batching engines: the dense fixed-slot baseline or the block-pool paged
engine (chunked prefill, admission on free pages, SPLS page pruning).

  PYTHONPATH=src python examples/serve_batch.py [--paged] [--spls]
"""

import argparse
import dataclasses
import time

import jax

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.spls import SPLSConfig
from repro.models import init_params
from repro.serving import (PagedServingEngine, Request, ServeConfig,
                           ServingEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--spls", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="block-pool paged KV cache engine")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--compute-backend", default=None,
                    choices=["dense", "packed_xla", "packed_pallas", "auto"],
                    help="end-to-end sparse compute on the SPLS chunked "
                         "prefill path (repro.sparse_compute)")
    ap.add_argument("--s-threshold", type=float, default=0.6,
                    help="SPLS similarity threshold (higher -> more rows "
                         "similar -> more packed-compute savings)")
    ap.add_argument("--vote-horizon", type=int, default=None,
                    help="finalize the SPLS column prune vote after this "
                         "many chunks instead of end-of-prefill "
                         "(core.planner; 1 packs the K/V projection)")
    ap.add_argument("--prune-vote", type=float, default=0.5,
                    help="cross-head agreement fraction a column must win "
                         "to keep its page slot (and, under a finite "
                         "--vote-horizon, to keep its K/V projection)")
    ap.add_argument("--k-ratio", type=float, default=0.25,
                    help="SPLS row-wise top-k ratio (smaller -> sparser "
                         "column votes -> more K/V pruning)")
    ap.add_argument("--capacity-margin", type=float, default=1.25,
                    help="capacity-controller safety margin over the EMA "
                         "estimate (1.0 = tightest buckets)")
    ap.add_argument("--prompt-repeat", type=int, default=None,
                    metavar="N",
                    help="make prompts repetitive: token i of every "
                         "prompt is drawn from an N-token motif pool "
                         "resampled every N positions (adjacent rows "
                         "become locally similar, so the SPLS packed "
                         "path actually sparsifies -- random prompts "
                         "barely do)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable the serving telemetry (no-op sinks; "
                         "back-compat stats counters keep working)")
    ap.add_argument("--bench-json", default=None, metavar="PATH",
                    help="write the telemetry-derived BENCH_serving.json "
                         "report to PATH (requires telemetry)")
    ap.add_argument("--trace-json", default=None, metavar="PATH",
                    help="write the Chrome trace (open in "
                         "https://ui.perfetto.dev) to PATH")
    args = ap.parse_args()
    if args.bench_json and args.no_telemetry:
        ap.error("--bench-json needs telemetry (drop --no-telemetry)")

    cfg = ArchConfig(
        name="serve-demo", n_layers=4, d_model=128, n_heads=8, n_kv_heads=4,
        head_dim=16, d_ff=512, vocab_size=512,
        period=(BlockCfg(mixer="attn"),), remat=False,
        spls=SPLSConfig(enabled=args.spls, k_ratio=args.k_ratio,
                        s_threshold=args.s_threshold,
                        f_threshold=3, window=8, causal=True))
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(n_slots=args.slots,
                       max_len=args.prompt_len + args.max_new + 8,
                       page_size=args.page_size,
                       prefill_chunk=args.prefill_chunk,
                       compute_backend=args.compute_backend,
                       vote_horizon=args.vote_horizon,
                       spls_prune_vote=args.prune_vote,
                       capacity_margin=args.capacity_margin,
                       telemetry=not args.no_telemetry)
    eng = (PagedServingEngine if args.paged else ServingEngine)(
        cfg, params, scfg)

    reqs = []
    for i in range(args.requests):
        if args.prompt_repeat:
            import numpy as np
            n = args.prompt_repeat
            motifs = np.asarray(jax.random.randint(
                jax.random.PRNGKey(100 + i),
                (args.prompt_len // n + 1,), 0, cfg.vocab_size))
            prompt = jax.numpy.asarray(
                np.repeat(motifs, n)[:args.prompt_len], jax.numpy.int32)
        else:
            prompt = jax.random.randint(jax.random.PRNGKey(100 + i),
                                        (args.prompt_len,), 0,
                                        cfg.vocab_size)
        r = Request(rid=i, prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(r)
        eng.submit(r)

    t0 = time.perf_counter()
    done = eng.run_until_drained(max_ticks=2000)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in reqs)
    print(f"requests={len(reqs)} slots={args.slots} paged={args.paged} "
          f"spls={args.spls} retired={len(done)}")
    print(f"decoded {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on CPU)")
    if args.paged:
        print(f"pool: peak_pages={eng.stats['peak_pages']} "
              f"preemptions={eng.stats['preemptions']} "
              f"prefill_chunks={eng.stats['prefill_chunks']}")
        fs = eng.stats["flops_saved_pct"]
        print(f"compute: backend={eng.stats['compute_backend']} "
              f"flops_saved qkv={fs['qkv']:.1f}% attn={fs['attn']:.1f}% "
              f"ffn={fs['ffn']:.1f}% kv={fs.get('kv', 0.0):.1f}%")
    assert all(r.done for r in reqs), "queue did not drain"
    assert len(done) == len(reqs)
    if args.bench_json:
        from repro.observability import serving_report, write_report

        report = serving_report(eng, wall_s=dt, extra={
            "workload": {"requests": args.requests,
                         "prompt_len": args.prompt_len,
                         "max_new": args.max_new,
                         "prompt_repeat": args.prompt_repeat}})
        write_report(args.bench_json, report)
        lat = report["latency"]
        print(f"wrote {args.bench_json} "
              f"(ttft_p50={lat['ttft_ms']['p50']:.1f}ms "
              f"tpot_p50={lat['tpot_ms']['p50']:.2f}ms)")
    if args.trace_json:
        eng.telemetry.trace.validate()
        eng.telemetry.trace.write(args.trace_json)
        print(f"wrote {args.trace_json} "
              f"({len(eng.telemetry.trace.events)} events; open in "
              f"https://ui.perfetto.dev)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.output}")


if __name__ == "__main__":
    main()
