"""Ablation: sweep the SPLS hyper-parameters (k, s, f) on a trained model
and print the sparsity / FLOPs-reduction / accuracy trade-off curve --
the offline analogue of the paper's Figs 16/19 grid search.

  PYTHONPATH=src python examples/spls_ablation.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.spls import SPLSConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import loss_fn
from repro.runtime import Trainer, TrainerConfig


def main():
    base = ArchConfig(
        name="ablate", n_layers=2, d_model=64, n_heads=8, n_kv_heads=8,
        head_dim=8, d_ff=256, vocab_size=64, period=(BlockCfg(),),
        remat=False)
    data = DataConfig(vocab_size=64, seq_len=64, global_batch=8, seed=11)

    # train dense once
    t = Trainer(base, TrainerConfig(total_steps=200, log_every=50,
                                    peak_lr=2e-3, warmup_steps=20), data)
    out = t.run()
    params = t.params
    dense_acc = out["metrics"][-1]["accuracy"]
    eval_batch = synthetic_batch(data, 10_000)
    print(f"dense: train-acc {dense_acc:.3f}")
    print(f"{'config':28s} {'eval_acc':>8s} {'delta':>8s}")

    _, dm = loss_fn(base, params, eval_batch)
    dense_eval = float(dm["accuracy"])
    print(f"{'dense':28s} {dense_eval:8.3f} {0.0:8.3f}")

    for k in (0.3, 0.2, 0.12):
        for s in (0.4, 0.6, 0.8):
            cfg = dataclasses.replace(base, spls=SPLSConfig(
                enabled=True, k_ratio=k, s_threshold=s, f_threshold=4,
                window=8, causal=True))
            _, m = loss_fn(cfg, params, eval_batch)
            acc = float(m["accuracy"])
            tag = f"spls k={k} s={s}"
            print(f"{tag:28s} {acc:8.3f} {acc - dense_eval:8.3f}")
    print("(apply-at-inference without fine-tuning; the paper fine-tunes "
          "under sparsity, which recovers most of the gap)")


if __name__ == "__main__":
    main()
