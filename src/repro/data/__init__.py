"""Deterministic, restart-safe data pipeline."""

from .pipeline import DataConfig, data_iterator, synthetic_batch
