"""Deterministic, restart-safe synthetic data pipeline.

Real deployments stream tokenized shards; for a self-contained framework we
generate synthetic batches from a counter-keyed PRNG, which gives the two
properties fault tolerance needs:

  * **determinism** -- batch ``i`` is a pure function of (seed, i), so a
    restarted job resumes mid-epoch by setting the step counter, with no
    state files beyond the checkpoint;
  * **shardability** -- each data-parallel rank draws only its slice.

Two task families: ``lm`` (token streams with a learnable k-gram structure
so accuracy is meaningful) and ``copy`` (diagnostic exact-match task).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "synthetic_batch", "data_iterator"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 256
    seq_len: int = 128
    global_batch: int = 8
    task: str = "lm"            # "lm" | "copy"
    seed: int = 0
    input_mode: str = "tokens"  # "tokens" | "embeddings"
    d_model: int = 0            # for embeddings mode
    ngram: int = 3              # structure order for the lm task


def _lm_tokens(key, cfg: DataConfig) -> jax.Array:
    """Markov-ish stream: next token = f(prev ngram) + noise, learnable."""
    B, L, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    k1, k2, k3 = jax.random.split(key, 3)
    # fixed random transition table derived from the seed only
    tkey = jax.random.PRNGKey(cfg.seed)
    table = jax.random.randint(tkey, (V,), 0, V)
    x0 = jax.random.randint(k1, (B, cfg.ngram), 0, V)
    noise = jax.random.bernoulli(k2, 0.1, (B, L))
    rand = jax.random.randint(k3, (B, L), 0, V)

    def step(carry, i):
        prev = carry
        det = table[prev[:, -1]] % V  # deterministic Markov successor
        nxt = jnp.where(noise[:, i], rand[:, i], det)
        carry = jnp.concatenate([prev[:, 1:], nxt[:, None]], axis=1)
        return carry, nxt

    _, toks = jax.lax.scan(step, x0, jnp.arange(L))
    return toks.T  # (B, L)


def _copy_tokens(key, cfg: DataConfig) -> jax.Array:
    B, L, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    half = L // 2
    pat = jax.random.randint(key, (B, half), 2, V)
    sep = jnp.full((B, 1), 1, jnp.int32)
    out = jnp.concatenate([pat, sep, pat], axis=1)[:, :L]
    return out.astype(jnp.int32)


def synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """Batch ``step`` -- pure function of (cfg.seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    toks = (_lm_tokens if cfg.task == "lm" else _copy_tokens)(key, cfg)
    toks = toks.astype(jnp.int32)
    inputs, labels = toks[:, :-1], toks[:, 1:]
    batch = {"labels": labels}
    if cfg.input_mode == "embeddings":
        ekey = jax.random.PRNGKey(cfg.seed + 1)
        table = jax.random.normal(ekey, (cfg.vocab_size, cfg.d_model))
        batch["inputs"] = table[inputs]
    else:
        batch["inputs"] = inputs
    if cfg.task == "copy":
        mask = jnp.zeros(labels.shape, jnp.float32)
        mask = mask.at[:, labels.shape[1] // 2:].set(1.0)
        batch["mask"] = mask
    return batch


def data_iterator(cfg: DataConfig, start_step: int = 0
                  ) -> Iterator[Dict[str, jax.Array]]:
    """Infinite restart-safe iterator (resume by passing the saved step)."""
    step = start_step
    while True:
        yield synthetic_batch(cfg, step)
        step += 1
