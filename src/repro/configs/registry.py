"""Config registry: ``--arch <id>`` resolution for launchers and tests."""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ArchConfig, LM_SHAPES, ShapeCfg

__all__ = ["ARCH_IDS", "get_config", "get_shape", "all_cells"]

# assignment id -> module name
_MODULES: Dict[str, str] = {
    "gemma2-27b": "gemma2_27b",
    "h2o-danube3-4b": "h2o_danube3_4b",
    "qwen3-0.6b": "qwen3_0_6b",
    "llama3-405b": "llama3_405b",
    "dbrx-132b": "dbrx_132b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "musicgen-medium": "musicgen_medium",
    "mamba2-370m": "mamba2_370m",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "pixtral-12b": "pixtral_12b",
    # the paper's own workload (not part of the 40-cell assignment)
    "bert-base-esact": "bert_base_esact",
}

ARCH_IDS: List[str] = [k for k in _MODULES if k != "bert-base-esact"]


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeCfg:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")


def all_cells(include_skipped: bool = False):
    """Yield every (arch, shape) cell of the assignment (40 total).

    Cells whose shape the arch does not support (long_500k on pure
    full-attention archs) are skipped unless ``include_skipped``.
    """
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape in LM_SHAPES:
            if shape.name in cfg.supported_shapes or include_skipped:
                yield arch_id, shape.name
