"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 -- Mamba+attention 1:7 interleave, MoE
every other layer.  [arXiv:2403.19887; hf]

Period of 8 layers: attention at index 4, mamba elsewhere; MoE on odd
indices (4 MoE / 4 dense per period).  4 periods = 32 layers.
long_500k: supported (hybrid -- mamba layers are O(1)/token, the 4 attn
layers read the cache).
"""

from repro.configs.base import ArchConfig, BlockCfg

_M = lambda moe: BlockCfg(mixer="mamba", use_moe=moe)
_A = lambda moe: BlockCfg(mixer="attn", use_moe=moe)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    period=(_M(False), _M(True), _M(False), _M(True),
            _A(False), _M(True), _M(False), _M(True)),
    moe_experts=16,
    moe_topk=2,
    capacity_factor=1.25,
    ssm_state=16,
    mamba_headdim=64,
    mamba_expand=2,
    conv_width=4,
    ffn_activation="silu",
    tied_embeddings=False,
    fsdp=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    microbatch={"train_4k": 4},
)
