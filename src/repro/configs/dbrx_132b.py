"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4, fine-grained.
[hf:databricks/dbrx-base; unverified]

long_500k: skipped -- pure full attention (see DESIGN.md).
"""

from repro.configs.base import ArchConfig, BlockCfg

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    period=(BlockCfg(mixer="attn", use_moe=True),),
    moe_experts=16,
    moe_topk=4,
    capacity_factor=1.25,
    ffn_activation="silu",
    tied_embeddings=False,
    rope_theta=500000.0,
    fsdp=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    microbatch={"train_4k": 4},
)
