"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24, MHA) d_ff=6144
vocab=2048 -- decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Modality frontend (EnCodec) is a STUB per the assignment: input_specs()
provides precomputed frame embeddings (B, L, d_model); the head predicts
EnCodec codebook tokens (vocab 2048).
long_500k: skipped -- pure full attention (see DESIGN.md).
"""

from repro.configs.base import ArchConfig, BlockCfg

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    period=(BlockCfg(mixer="attn"),),
    ffn_activation="gelu_mlp",
    input_mode="embeddings",
    tied_embeddings=False,
    param_dtype="float32",
    compute_dtype="bfloat16",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    microbatch={"train_4k": 4},
)
