"""Architecture configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`: a layer
*pattern* (one period of possibly-heterogeneous blocks, repeated
``n_periods`` times and scanned over), attention/SSM/MoE hyper-parameters,
numerics, and the SPLS settings for the paper's technique.  ``smoke()``
returns a structurally identical but tiny config for CPU tests; the full
config is only ever lowered abstractly (dry-run).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core.spls import SPLSConfig

__all__ = ["BlockCfg", "ArchConfig", "ShapeCfg", "LM_SHAPES"]


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    """One block inside the layer period."""

    mixer: str = "attn"            # "attn" | "mamba"
    window: Optional[int] = None   # sliding-window size (None = global)
    use_moe: bool = False
    has_ffn: bool = True           # mamba2-pure blocks have no FFN


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One input-shape cell from the assignment table."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


LM_SHAPES: Tuple[ShapeCfg, ...] = (
    ShapeCfg("train_4k", 4096, 256, "train"),
    ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    ShapeCfg("decode_32k", 32768, 128, "decode"),
    ShapeCfg("long_500k", 524288, 1, "decode"),
)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str = "unnamed"
    family: str = "dense"          # dense | moe | ssm | hybrid | audio | vlm
    # dimensions
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None  # default d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 1024
    # layer pattern: `period` repeated `n_periods` times (scanned)
    period: Tuple[BlockCfg, ...] = (BlockCfg(),)
    # attention features
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    causal: bool = True
    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    capacity_factor: float = 1.25
    # Mamba2 / SSD
    ssm_state: int = 0
    mamba_headdim: int = 64
    mamba_expand: int = 2
    conv_width: int = 4
    # embedding / IO
    input_mode: str = "tokens"      # "tokens" | "embeddings" (modality stub)
    tied_embeddings: bool = True
    norm_eps: float = 1e-6
    ffn_activation: str = "silu"    # silu (gated) | gelu (gated) | gelu_mlp
    use_post_norm: bool = False     # gemma2-style post-block norms
    scale_embedding: bool = False   # multiply embeddings by sqrt(d_model)
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # FSDP: additionally shard every large parameter (and its optimizer
    # moments) over the in-pod data axis; XLA all-gathers weights per layer
    # inside the scan (ZeRO-3 semantics).  Required for archs whose
    # params+opt exceed HBM under tensor parallelism alone.
    fsdp: bool = False
    # SPLS (the paper's technique); None-like default = disabled
    spls: SPLSConfig = SPLSConfig(enabled=False)
    # attention execution backend (repro.models.attn_backend registry):
    # "auto" | "xla_dense" | "xla_packed" | "xla_chunked" | "pallas_flash"
    # | decode: "xla_dense_decode" | "pallas_flash_decode".  "auto" picks by
    # platform, sequence length, and sparsity mode (models/README.md).
    attn_backend: str = "auto"
    # compute execution backend for the token-compacted *linear* ops (QKV
    # projection / FFN) under SPLS (repro.sparse_compute registry):
    # "dense" | "packed_xla" | "packed_pallas" | "auto".  "dense" keeps
    # every existing path byte-identical; packed backends compute only
    # critical rows and broadcast leaders (models/README.md).
    compute_backend: str = "dense"
    # training
    remat: bool = True
    # shape support: names from LM_SHAPES this arch can run; long_500k only
    # for sub-quadratic archs (SSM / hybrid / SWA) per the assignment note.
    supported_shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    # per-shape microbatch override for gradient accumulation {shape: mb}
    microbatch: Optional[dict] = None

    # ------------------------------------------------------------------
    def __post_init__(self):
        if len(self.period) == 0:
            raise ValueError("period must contain at least one block")
        if self.n_layers % len(self.period):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"period length {len(self.period)}")

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_nheads(self) -> int:
        return self.d_inner // self.mamba_headdim

    @property
    def has_attn(self) -> bool:
        return any(b.mixer == "attn" for b in self.period)

    @property
    def has_mamba(self) -> bool:
        return any(b.mixer == "mamba" for b in self.period)

    @property
    def has_moe(self) -> bool:
        return any(b.use_moe for b in self.period)

    def moe_capacity(self, n_tokens: int) -> int:
        """Per-expert token capacity, rounded up to a multiple of 8."""
        c = math.ceil(n_tokens * self.moe_topk * self.capacity_factor
                      / max(self.moe_experts, 1))
        return max(8, -(-c // 8) * 8)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, Dh = self.d_model, self.resolved_head_dim
        n = self.vocab_size * D  # embed
        if not self.tied_embeddings:
            n += D * self.vocab_size
        per_period = 0
        for b in self.period:
            if b.mixer == "attn":
                per_period += D * self.n_heads * Dh          # wq
                per_period += 2 * D * self.n_kv_heads * Dh   # wk, wv
                per_period += self.n_heads * Dh * D          # wo
            else:
                di, ds, nh = self.d_inner, self.ssm_state, self.mamba_nheads
                per_period += D * (2 * di + 2 * ds + nh)     # in_proj
                per_period += (di + 2 * ds) * self.conv_width
                per_period += di * D                          # out_proj
                per_period += 3 * nh + di                     # A, D, dt_bias, norm
            if b.has_ffn:
                mult = 3 if self.ffn_activation in ("silu", "gelu") else 2
                f = mult * D * self.d_ff
                if b.use_moe:
                    per_period += self.moe_experts * f + D * self.moe_experts
                else:
                    per_period += f
            per_period += 2 * D  # norms
        return n + per_period * self.n_periods + D

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.has_moe:
            return self.param_count()
        D = self.d_model
        mult = 3 if self.ffn_activation in ("silu", "gelu") else 2
        f = mult * D * self.d_ff
        dead = sum((self.moe_experts - self.moe_topk) * f
                   for b in self.period if b.use_moe) * self.n_periods
        return self.param_count() - dead

    # ------------------------------------------------------------------
    def smoke(self) -> "ArchConfig":
        """Structurally identical, CPU-sized variant for tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 * len(self.period) if len(self.period) <= 2 else len(self.period),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            moe_experts=min(self.moe_experts, 4) if self.moe_experts else 0,
            moe_topk=min(self.moe_topk, 2) if self.moe_topk else 0,
            ssm_state=16 if self.ssm_state else 0,
            mamba_headdim=16,
            period=tuple(dataclasses.replace(
                b, window=min(b.window, 8) if b.window else None)
                for b in self.period),
            param_dtype="float32",
            compute_dtype="float32",
            spls=dataclasses.replace(self.spls, window=4)
            if self.spls.enabled else self.spls,
        )
