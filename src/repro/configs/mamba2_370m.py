"""mamba2-370m [ssm]: 48L d_model=1024, attention-free, vocab=50280,
ssm_state=128 -- SSD (state-space duality).  [arXiv:2405.21060; unverified]

long_500k: supported -- recurrent decode has O(1) state per token.
SPLS inapplicability: no attention matrix exists, so the paper's technique
does not apply (DESIGN.md §Arch-applicability); the arch runs dense.
vocab 50280 is not divisible by the 16-way model axis; the sharding layer
replicates the embedding (divisibility fallback).
"""

from repro.configs.base import ArchConfig, BlockCfg

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    period=(BlockCfg(mixer="mamba", has_ffn=False),),
    ssm_state=128,
    mamba_headdim=64,
    mamba_expand=2,
    conv_width=4,
    tied_embeddings=True,
    param_dtype="float32",
    compute_dtype="bfloat16",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    microbatch={"train_4k": 8},
)
