"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 -- pixtral-ViT frontend + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]

Modality frontend (the ViT) is a STUB per the assignment: input_specs()
provides precomputed patch+text embeddings (B, L, d_model).
long_500k: skipped -- pure full attention (see DESIGN.md).
"""

from repro.configs.base import ArchConfig, BlockCfg

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    period=(BlockCfg(mixer="attn"),),
    ffn_activation="silu",
    input_mode="embeddings",
    tied_embeddings=False,
    rope_theta=1000000000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    microbatch={"train_4k": 2},
)
