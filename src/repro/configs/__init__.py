"""Architecture configs. Use repro.configs.registry.get_config(name)."""

from .base import ArchConfig, BlockCfg, ShapeCfg, LM_SHAPES
