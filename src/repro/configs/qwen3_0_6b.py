"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 -- qk-norm, GQA.  [hf:Qwen/Qwen3-8B; hf]

long_500k: skipped -- pure full attention (see DESIGN.md).
"""

from repro.configs.base import ArchConfig, BlockCfg

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    period=(BlockCfg(mixer="attn"),),
    qk_norm=True,
    ffn_activation="silu",
    tied_embeddings=True,
    rope_theta=1000000.0,
    param_dtype="float32",
    compute_dtype="bfloat16",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    microbatch={"train_4k": 8},
)
