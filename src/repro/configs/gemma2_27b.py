"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 -- local+global alternating attention, logit soft-capping.
[arXiv:2408.00118; hf]

long_500k: supported -- half the layers are SWA(4096) and the cell is a
*decode* step (O(cache) per token); the global layers read the full cache.
"""

from repro.configs.base import ArchConfig, BlockCfg

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    period=(BlockCfg(mixer="attn", window=4096), BlockCfg(mixer="attn")),
    attn_softcap=50.0,
    final_softcap=30.0,
    ffn_activation="gelu",        # GeGLU
    use_post_norm=True,
    scale_embedding=True,
    tied_embeddings=True,
    rope_theta=10000.0,
    fsdp=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    microbatch={"train_4k": 4},
)
