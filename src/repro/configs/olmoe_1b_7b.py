"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8.  [arXiv:2409.02060; hf]

long_500k: skipped -- pure full attention (see DESIGN.md).
"""

from repro.configs.base import ArchConfig, BlockCfg

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    period=(BlockCfg(mixer="attn", use_moe=True),),
    moe_experts=64,
    moe_topk=8,
    capacity_factor=1.25,
    qk_norm=True,
    ffn_activation="silu",
    tied_embeddings=False,
    rope_theta=10000.0,
    param_dtype="float32",
    compute_dtype="bfloat16",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    microbatch={"train_4k": 4},
)
