"""The paper's own primary workload: BERT-Base encoder with SPLS enabled.

Used by the reproduction benchmarks (Fig. 15/16/17/18/19) and examples.
Non-causal, MHA, GELU MLP, seq 128/384/512 per the GLUE/SQuAD/CLOTH setup.
"""

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.spls import SPLSConfig

CONFIG = ArchConfig(
    name="bert-base-esact",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=30522,
    period=(BlockCfg(mixer="attn"),),
    causal=False,
    ffn_activation="gelu_mlp",
    tied_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
    spls=SPLSConfig(enabled=True, k_ratio=0.12, s_threshold=0.6,
                    f_threshold=6, window=8, causal=False),
    supported_shapes=("train_4k", "prefill_32k"),
    microbatch={"train_4k": 8},
)
