"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 -- GQA, 128k vocab.  [arXiv:2407.21783; unverified]

long_500k: skipped -- pure full attention (see DESIGN.md).
bf16 params + optimizer state to fit 16 GB/chip HBM at 512 chips
(see DESIGN.md hardware-adaptation notes).
"""

from repro.configs.base import ArchConfig, BlockCfg

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    period=(BlockCfg(mixer="attn"),),
    ffn_activation="silu",
    tied_embeddings=False,
    rope_theta=500000.0,
    fsdp=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    microbatch={"train_4k": 4},
)
