"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 -- llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]

long_500k: supported -- every layer is SWA, decode touches a bounded window.
"""

from repro.configs.base import ArchConfig, BlockCfg

CONFIG = ArchConfig(
    name="h2o-danube3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    period=(BlockCfg(mixer="attn", window=4096),),
    ffn_activation="silu",
    tied_embeddings=False,
    rope_theta=10000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    microbatch={"train_4k": 2},
)
