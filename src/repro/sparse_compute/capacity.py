"""Capacity controller: observed critical-row counts -> bucketed static
capacities.

XLA cannot execute dynamic row counts, so packed compute runs at a static
capacity per jit -- but jitting one program per *exact* count would
compile once per chunk.  The controller is the middle ground (the same
single-jit discipline the progressive plan uses for its traced top-k):
a small static **bucket set**, an EMA of the observed counts, and a
safety margin.  Each chunk picks the smallest bucket covering the
margin-scaled estimate, so the engine compiles at most ``len(buckets)``
variants and under-capacity chunks degrade gracefully (overflow rows
fall back to their window leader -- :func:`repro.core.sparse_exec.compact_rows`)
instead of recompiling.

This is the TPU analogue of the ASIC's dynamic-allocation FIFO scheduler
(Sec. IV-D): load balance comes from the pack, dynamic sizing from the
bucket choice, and "FIFO recovery" is the leader gather.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

__all__ = ["CapacityController", "default_buckets"]


def default_buckets(total: int, align: int = 8) -> Tuple[int, ...]:
    """Quarter-steps of ``total`` aligned up to ``align`` (always includes
    ``total`` itself, so full capacity -- exact numerics -- is reachable)."""
    align = max(1, align)
    up = lambda v: min(total, -(-v // align) * align)
    return tuple(sorted({up(max(1, (total * q) // 4)) for q in (1, 2, 3)}
                        | {total}))


class CapacityController:
    """EMA-tracked critical-row counts bucketed into static capacities.

    ``total`` is the full row count (the chunk size): the first chunk --
    before any observation -- runs at ``total``, i.e. exact, and every
    later chunk at the smallest bucket covering ``ceil(margin * ema)``.
    ``margin`` trades wasted slots against overflow fallbacks.
    """

    def __init__(self, total: int, align: int = 8,
                 buckets: Optional[Sequence[int]] = None,
                 margin: float = 1.25, ema: float = 0.5):
        if total < 1:
            raise ValueError(f"capacity total must be >= 1, got {total}")
        self.total = total
        self.buckets = tuple(sorted(
            {min(total, max(1, int(b))) for b in buckets} | {total}
        )) if buckets is not None else default_buckets(total, align)
        self.margin = margin
        self.ema = ema
        self._est: Optional[float] = None
        self.stats = {"observations": 0, "overflows": 0,
                      "picks": {b: 0 for b in self.buckets}}

    def observe(self, n_critical: int) -> None:
        """Record a chunk's observed critical-row count (post-execution).
        Counts above the capacity served are still observed -- that is how
        the estimate recovers after an overflow."""
        n = float(n_critical)
        self._est = n if self._est is None else (
            (1.0 - self.ema) * self._est + self.ema * n)
        self.stats["observations"] += 1

    def note_overflow(self) -> None:
        self.stats["overflows"] += 1

    def capacity(self) -> int:
        """Smallest bucket covering the margin-scaled estimate; ``total``
        (exact) until the first observation."""
        if self._est is None:
            pick = self.total
        else:
            need = min(self.total, max(1, math.ceil(self.margin * self._est)))
            pick = next((b for b in self.buckets if b >= need), self.total)
        self.stats["picks"][pick] = self.stats["picks"].get(pick, 0) + 1
        return pick

    @property
    def overflow_rate(self) -> float:
        """Fraction of observed chunks that overflowed their bucket into
        the window-leader fallback (0.0 before any observation)."""
        obs = self.stats["observations"]
        return self.stats["overflows"] / obs if obs else 0.0

    def snapshot(self) -> dict:
        """Telemetry view: the raw stats plus the live EMA estimate, the
        overflow-fallback rate, and the mean bucket occupancy (picked
        slots actually demanded, weighted by picks)."""
        picks = dict(self.stats["picks"])
        n_picks = sum(picks.values())
        mean_bucket = (sum(b * n for b, n in picks.items()) / n_picks
                       if n_picks else float(self.total))
        return {**self.stats, "picks": picks, "estimate": self._est,
                "overflow_rate": self.overflow_rate,
                "mean_bucket": mean_bucket,
                "occupancy": (self._est / mean_bucket
                              if self._est is not None and mean_bucket
                              else None)}
