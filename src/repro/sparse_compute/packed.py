"""Packed execution of the SPLS-sparsified linear ops.

Two operations, both dispatched through the compute-backend registry
(:mod:`repro.sparse_compute.backend`) and both row-for-row bitwise equal
to their dense counterparts (row subsets of an XLA dot are bitwise
stable; the Pallas backend runs the whole contraction per tile -- see
``kernels/gathered_matmul.py``):

* :func:`packed_project_q` -- Q projection of a packed row subset in the
  structured GQA layout, RoPE'd at the rows' *original* positions.  The
  serving prefill packs Q to the **cross-head union** of critical rows:
  every head's leaders are in the union, so per-head leader recovery
  reads slots that were actually computed, and the single
  ``(C, D) @ (D, H*Dh)`` matmul keeps the MXU dense (per-head row sets
  would fragment it).
* :func:`packed_mlp` -- the dense (gated) MLP on FFN-critical token rows
  with leader broadcast, mirroring :func:`repro.models.moe.mlp_forward`
  einsum-for-einsum.  MoE blocks are not packed (their capacity routing
  already is the pack).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparse_exec import Compaction
from repro.models.common import Activations, apply_rope, rms_norm, rope_freqs

from .backend import get_compute_backend

__all__ = ["packed_project_q", "packed_project_kv", "packed_mlp"]


def packed_project_q(cfg, p: dict, xn: jax.Array, positions: jax.Array,
                     perm: jax.Array, backend: str) -> jax.Array:
    """Project Q for a packed row subset (B = 1, structured layout).

    xn: (1, L, D) normalized block input; positions: (L,) original row
    ids; perm: (C,) packed source rows.  Returns ``(1, KV, G, C, Dh)``
    whose slot ``c`` is bit-for-bit row ``perm[c]`` of
    :func:`repro.models.attention.project_qkv`'s q output (einsum row
    subset + row-wise qk-norm/RoPE) -- the parity tests pin this.
    """
    D, KV, Dh = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    C = perm.shape[0]
    wq2 = p["wq"].reshape(D, KV * G * Dh)
    be = get_compute_backend(backend)
    qg = be.gathered_matmul(xn[0], wq2, perm)            # (C, KV*G*Dh)
    q = qg.reshape(1, C, KV, G, Dh).transpose(0, 2, 3, 1, 4)
    q = q.astype(xn.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    pos_p = jnp.take(positions, perm)[None, :]           # (1, C)
    sin, cos = rope_freqs(pos_p, Dh, cfg.rope_theta)
    return apply_rope(q, sin[:, None, None], cos[:, None, None])


def packed_project_kv(cfg, p: dict, xn: jax.Array, positions: jax.Array,
                      perm: jax.Array, backend: str):
    """Project K/V for a packed column subset (B = 1, structured layout).

    xn: (1, L, D) normalized block input; positions: (L,) original slot
    ids; perm: (C,) packed source rows (the horizon-finalized keep
    decision of :func:`repro.core.planner.own_column_keep`, packed by
    :func:`repro.core.sparse_exec.pack_by_mask`).  Returns
    ``(k, v)`` of shape ``(1, KV, C, Dh)`` whose slot ``c`` is
    bit-for-bit row ``perm[c]`` of
    :func:`repro.models.attention.project_kv`'s dense output (einsum row
    subset + row-wise k-norm/RoPE at the original positions) -- the
    parity tests pin this.  This is the K/V half of the paper's
    end-to-end sparsity: columns the horizon vote finalized as pruned are
    never projected at all.
    """
    D, KV, Dh = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
    C = perm.shape[0]
    be = get_compute_backend(backend)
    kg = be.gathered_matmul(xn[0], p["wk"].reshape(D, KV * Dh), perm)
    vg = be.gathered_matmul(xn[0], p["wv"].reshape(D, KV * Dh), perm)
    k = kg.reshape(1, C, KV, Dh).transpose(0, 2, 1, 3).astype(xn.dtype)
    v = vg.reshape(1, C, KV, Dh).transpose(0, 2, 1, 3).astype(xn.dtype)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    pos_p = jnp.take(positions, perm)[None, :]           # (1, C)
    sin, cos = rope_freqs(pos_p, Dh, cfg.rope_theta)
    k = apply_rope(k, sin[:, None], cos[:, None])
    return k, v


def packed_mlp(cfg, p: dict, x: jax.Array, comp: Compaction,
               backend: str) -> jax.Array:
    """Dense (gated) MLP on packed critical rows + leader broadcast.

    x: (B, L, D); comp: compaction over (B, L) (FFN-critical rows packed,
    per-row read slots resolved).  Returns (B, L, D): critical rows carry
    their own MLP output, similar rows their MFI leader's, overflow rows
    their window leader's.  Batch rows flatten into the gather indices so
    one kernel call serves the whole batch.
    """
    B, L, D = x.shape
    C = comp.perm.shape[-1]
    act = Activations.fn(cfg.ffn_activation)
    be = get_compute_backend(backend)
    perm = (comp.perm + jnp.arange(B, dtype=jnp.int32)[:, None] * L
            ).reshape(-1)
    slot = (comp.src_slot + jnp.arange(B, dtype=jnp.int32)[:, None] * C
            ).reshape(-1)
    x2 = x.reshape(B * L, D)
    up = be.gathered_matmul(x2, p["w_up"], perm)         # (B*C, F)
    if "w_gate" in p:
        up = up * act(be.gathered_matmul(x2, p["w_gate"], perm))
    else:
        up = act(up)
    up = up.astype(x.dtype)
    down = jnp.einsum("cf,fd->cd", up, p["w_down"])      # rows already packed
    return be.gather_rows(down, slot).reshape(B, L, D)
