"""Analytic FLOPs for serving prefill chunks: dense vs executed.

Counts multiply-accumulates x2 (mul + add), the same convention as
:mod:`repro.core.flops`, for the three components the paper sparsifies --
QKV generation, attention score/value math, and the FFN -- as one
serving prefill chunk executes them.  The engine feeds these into the
scheduler's lifetime-FLOPs accounting so ``flops_saved_pct`` is tracked
per component from real serving runs (Fig. 15's breakdown, measured on
the serving path instead of derived from plan masks).

Serving-specific honesty notes:

* the output projection stays **dense** on the prefill path (its input
  is a per-row head mixture); the K/V projections stay dense *unless*
  the horizon-finalized prune vote is active with ``vote_horizon == 1``
  (``kv_rows``): only then are a chunk's own pruned columns skipped
  before projection (:mod:`repro.core.planner`).  The ``kv`` component
  reports that share on its own so the saving is attributable.
* attention cost is the packed row count times *all columns seen so
  far* (cross-chunk causal attention), for dense and packed alike.
* padded chunk rows are charged like real rows: the engine executes
  them (static shapes), and the dense baseline pays the same padding.
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple

from repro.models.common import Activations

__all__ = ["chunk_flops", "saved_pct"]


def saved_pct(acc: Dict[str, Iterable[float]]) -> Dict[str, float]:
    """Percent of dense-equivalent FLOPs *not* executed, per component,
    from a ``{component: (dense_total, executed_total)}`` accumulator
    (the scheduler's lifetime shape; 0.0 for components never run).
    Shared by ``Scheduler.flops_saved_pct`` and the telemetry report so
    every surface derives the number one way."""
    out = {}
    for c, (dense, executed) in acc.items():
        out[c] = 100.0 * (1.0 - executed / dense) if dense > 0 else 0.0
    return out


def chunk_flops(cfg, rows: int, cols: int, q_rows: Optional[int] = None,
                ffn_rows: Optional[int] = None,
                kv_rows: Optional[int] = None
                ) -> Dict[str, Tuple[float, float]]:
    """Per-chunk (dense, executed) FLOPs for qkv / attn / ffn / kv.

    rows: chunk rows executed (the static chunk size); cols: KV columns
    attended (slots written so far, incl. this chunk); q_rows /
    ffn_rows / kv_rows: packed capacities actually computed (None =
    dense).  ``kv`` is the K/V-projection share reported standalone
    (it is also folded into ``qkv`` for the combined view).  Counts
    cover every attention block of the whole model (the paged engine is
    attention-only).
    """
    D, KV, Dh = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
    H = cfg.n_heads
    n_attn = len(cfg.period) * cfg.n_periods
    n_ffn = sum(1 for b in cfg.period if b.has_ffn) * cfg.n_periods
    mult = 3 if Activations.gated(cfg.ffn_activation) else 2

    q_rows = rows if q_rows is None else min(q_rows, rows)
    ffn_rows = rows if ffn_rows is None else min(ffn_rows, rows)
    kv_rows = rows if kv_rows is None else min(kv_rows, rows)

    def kv(nkv):
        return 2.0 * 2.0 * nkv * D * KV * Dh * n_attn    # K and V projections

    def qkv(nq, nkv):
        q = 2.0 * nq * D * H * Dh
        wo = 2.0 * rows * H * Dh * D              # out-proj stays dense
        return (q + wo) * n_attn + kv(nkv)

    def attn(nq):
        return 2.0 * 2.0 * H * nq * cols * Dh * n_attn   # QK^T + AV

    def ffn(nf):
        return mult * 2.0 * nf * D * cfg.d_ff * n_ffn

    return {"qkv": (qkv(rows, rows), qkv(q_rows, kv_rows)),
            "attn": (attn(rows), attn(q_rows)),
            "ffn": (ffn(rows), ffn(ffn_rows)),
            "kv": (kv(rows), kv(kv_rows))}
