"""Compute-backend registry: how token-compacted linear ops execute.

Mirrors the attention backend registry (:mod:`repro.models.attn_backend`)
for the *linear* ops that SPLS sparsifies -- QKV projection and the FFN.
Every backend provides the same two primitives:

    gathered_matmul(x, w, perm, src_slot=None)  ->  (C, F) or (M, F)
    gather_rows(rows, idx)                      ->  rows[..., idx, :]

with ``x: (L, D)`` source rows, ``perm: (C,)`` packed row indices, and
``src_slot: (M,)`` the packed slot each output row reads (the leader
broadcast).

  * ``dense``         -- compute every row, gather afterwards: the
    simulation-mode semantics (zero compute saving; the numerics oracle).
  * ``packed_xla``    -- XLA ``pack_by_mask``-style execution: gather the
    packed rows, matmul at the reduced size, scatter through the leader
    map.  Row subsets of an XLA dot are bitwise-stable, so this path is
    bit-for-bit equal to ``dense`` whenever capacity covers every
    critical row.
  * ``packed_pallas`` -- :mod:`repro.kernels.gathered_matmul`: the gather
    rides in the matmul's DMA schedule (scalar-prefetched row indices,
    per-row async copies into the VMEM panel) and the leader scatter is a
    BlockSpec-index-map gather.  Compiled on TPU, ``interpret=True``
    elsewhere (bit-accurate, slow).

``"auto"`` resolves from the platform and whether a sparsity plan exists;
the ``dense`` default keeps every existing path byte-identical until a
caller opts in.
"""

from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AUTO", "DENSE", "register_compute_backend",
           "get_compute_backend", "available_compute_backends",
           "resolve_compute_backend", "is_packed"]

AUTO = "auto"
DENSE = "dense"


class _ComputeBackend(NamedTuple):
    gathered_matmul: Callable
    gather_rows: Callable
    doc: str


_REGISTRY: Dict[str, _ComputeBackend] = {}


def register_compute_backend(name: str, gathered_matmul: Callable,
                             gather_rows: Callable, doc: str = "") -> None:
    _REGISTRY[name] = _ComputeBackend(gathered_matmul, gather_rows, doc)


def available_compute_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_compute_backend(name: str) -> _ComputeBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown compute backend {name!r}; "
            f"registered: {available_compute_backends()}") from None


def is_packed(name: Optional[str]) -> bool:
    """True for backends that actually shrink the computed row count."""
    return name in ("packed_xla", "packed_pallas")


def _platform() -> str:
    return jax.default_backend()


def resolve_compute_backend(name: Optional[str], *, sparse: bool,
                            platform: Optional[str] = None) -> str:
    """Map a configured compute-backend name (possibly ``"auto"``/None) to
    a concrete registry key.

    ``auto``: without a sparsity plan there is nothing to pack ->
    ``dense``; with one, the Pallas fusion on TPU and the XLA pack/unpack
    path elsewhere.  Packed backends without SPLS are a configuration
    error (there is no critical-row structure to pack by) and raise.
    """
    name = name or AUTO
    if name == AUTO:
        if not sparse:
            return DENSE
        platform = platform or _platform()
        return "packed_pallas" if platform == "tpu" else "packed_xla"
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown compute backend {name!r}; "
            f"registered: {available_compute_backends()}")
    if is_packed(name) and not sparse:
        raise ValueError(
            f"compute backend {name!r} packs SPLS critical rows, but SPLS "
            f"is disabled (spls.enabled=False): there is no sparsity plan "
            f"to pack by -- use 'dense' or enable SPLS")
    return name


# ---------------------------------------------------------------------------
# registrations
# ---------------------------------------------------------------------------

def _xla_gather_rows(rows: jax.Array, idx: jax.Array) -> jax.Array:
    return jnp.take(rows, idx, axis=0)


def _dense_gathered_matmul(x: jax.Array, w: jax.Array, perm: jax.Array,
                           src_slot: Optional[jax.Array] = None) -> jax.Array:
    # simulation-mode semantics: every row computed, results gathered
    full = jnp.einsum("ld,df->lf", x, w)
    out = jnp.take(full, perm, axis=0)
    return out if src_slot is None else jnp.take(out, src_slot, axis=0)


def _packed_xla_gathered_matmul(x: jax.Array, w: jax.Array, perm: jax.Array,
                                src_slot: Optional[jax.Array] = None
                                ) -> jax.Array:
    out = jnp.einsum("cd,df->cf", jnp.take(x, perm, axis=0), w)
    return out if src_slot is None else jnp.take(out, src_slot, axis=0)


def _packed_pallas_gathered_matmul(x: jax.Array, w: jax.Array,
                                   perm: jax.Array,
                                   src_slot: Optional[jax.Array] = None
                                   ) -> jax.Array:
    from repro.kernels.gathered_matmul import gathered_matmul

    return gathered_matmul(x, w, perm, src_slot=src_slot,
                           interpret=_platform() != "tpu")


def _packed_pallas_gather_rows(rows: jax.Array, idx: jax.Array) -> jax.Array:
    from repro.kernels.gathered_matmul import gather_rows_kernel

    return gather_rows_kernel(rows, idx, interpret=_platform() != "tpu")


register_compute_backend(
    DENSE, _dense_gathered_matmul, _xla_gather_rows,
    doc="compute every row, gather afterwards (simulation-mode oracle)")
register_compute_backend(
    "packed_xla", _packed_xla_gathered_matmul, _xla_gather_rows,
    doc="XLA gather -> reduced matmul -> leader scatter")
register_compute_backend(
    "packed_pallas", _packed_pallas_gathered_matmul,
    _packed_pallas_gather_rows,
    doc="Pallas fused gather/matmul; scatter as BlockSpec index-map DMA")
