"""End-to-end sparse compute: token-compacted QKV + FFN execution.

The paper's headline claim is *end-to-end* sparsity -- SPLS predicts the
attention pattern before QK generation so that QKV projection, attention,
**and** the FFN all execute sparsely (Sec. III, Fig. 15).  This package is
the TPU-native realization of that claim for static-shape execution:

* :mod:`backend` -- the **compute-backend registry axis** (``dense`` |
  ``packed_xla`` | ``packed_pallas``), mirroring the attention backend
  registry (:mod:`repro.models.attn_backend`), so training/simulation and
  serving select how token-compacted linear ops execute through one
  dispatch;
* :mod:`packed` -- packed execution of the linear ops: Q projection on
  the critical-row union and the dense (gated) MLP on FFN-critical
  tokens, with leader broadcast recovering full-length outputs.  The
  ``packed_pallas`` backend fuses the row gather into the matmul's DMA
  schedule (:mod:`repro.kernels.gathered_matmul`);
* :mod:`capacity` -- the **capacity controller** that turns observed
  critical-row counts into a small set of bucketed static capacities
  (one jit per bucket -- XLA's static-shape discipline applied to the
  ASIC's dynamic-allocation FIFO scheduler);
* :mod:`accounting` -- analytic FLOPs (dense vs executed) per serving
  prefill chunk, feeding the scheduler's lifetime-FLOPs accounting.

The plan->compaction adapters live in :mod:`repro.core.sparse_exec`
(:class:`~repro.core.sparse_exec.Compaction`, ``compact_rows``).
"""

from .accounting import chunk_flops, saved_pct
from .backend import (AUTO, DENSE, available_compute_backends,
                      get_compute_backend, is_packed,
                      register_compute_backend, resolve_compute_backend)
from .capacity import CapacityController
from .packed import packed_mlp, packed_project_q

__all__ = [
    "AUTO", "DENSE", "available_compute_backends", "get_compute_backend",
    "is_packed", "register_compute_backend", "resolve_compute_backend",
    "CapacityController", "packed_mlp", "packed_project_q", "chunk_flops",
    "saved_pct",
]
