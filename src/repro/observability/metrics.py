"""Host-side metrics registry: counters, gauges, histograms.

Low-overhead by construction: every instrument is a plain Python object
mutated from host code *after* device readback -- nothing here is ever
traced, and timestamps come from an **injected monotonic clock**
(``Registry(clock=...)``), never ``time.time()`` inside jit.  The
serving engine records a handful of integer increments per tick, the
same cost as the ad-hoc ``stats`` dict this module replaces.

Naming convention is ``scope/name`` strings (``"pool/pages_in_use"``,
``"spls/kept_ratio"``); per-request data lives in
:class:`~repro.observability.trace.TraceRecorder` spans and the request
records the report builder aggregates, not in per-request instruments.

A disabled registry (``MetricsRegistry(enabled=False)``) hands out a
shared :class:`NullInstrument` that accepts every operation and records
nothing, so call sites never branch on the telemetry knob.
"""

from __future__ import annotations

import math
import time
from collections.abc import MutableMapping
from typing import Dict, List, Optional

__all__ = ["Counter", "CounterDictView", "Gauge", "Histogram",
           "MetricsRegistry", "NullInstrument", "percentile"]


def percentile(values: List[float], p: float) -> float:
    """Linear-interpolated percentile of ``values`` (``p`` in [0, 100]),
    matching ``numpy.percentile``'s default method.  NaN on empty."""
    if not values:
        return float("nan")
    xs = sorted(values)
    n = len(xs)
    if n == 1:
        return float(xs[0])
    rank = (p / 100.0) * (n - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, n - 1)
    frac = rank - lo
    return float(xs[lo] + (xs[hi] - xs[lo]) * frac)


class Counter:
    """Monotone event count.  ``set`` exists only for the back-compat
    ``stats`` dict view (legacy code assigns into it)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set(self, v: int) -> None:
        self.value = v

    def snapshot(self):
        return self.value


class Gauge:
    """Last-observed value with a high-watermark (and low-watermark)."""

    __slots__ = ("name", "value", "high", "low")

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0.0
        self.high: float = float("-inf")
        self.low: float = float("inf")

    def set(self, v: float) -> None:
        self.value = v
        if v > self.high:
            self.high = v
        if v < self.low:
            self.low = v

    def snapshot(self):
        return {"value": self.value,
                "high": self.high if self.high != float("-inf") else None,
                "low": self.low if self.low != float("inf") else None}


class Histogram:
    """Raw-sample histogram with percentile summaries.

    Samples are kept verbatim up to ``max_samples`` (serving smoke scale
    is thousands of observations, not millions); beyond the cap new
    samples are dropped and counted in ``dropped`` so truncation is
    visible instead of silent.
    """

    __slots__ = ("name", "samples", "count", "total", "max_samples",
                 "dropped")

    def __init__(self, name: str, max_samples: int = 100_000):
        self.name = name
        self.samples: List[float] = []
        self.count = 0
        self.total = 0.0
        self.max_samples = max_samples
        self.dropped = 0

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if len(self.samples) < self.max_samples:
            self.samples.append(float(v))
        else:
            self.dropped += 1

    def percentile(self, p: float) -> float:
        return percentile(self.samples, p)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def summary(self) -> dict:
        return {"n": self.count, "mean": self.mean,
                "p50": self.percentile(50.0), "p99": self.percentile(99.0),
                "min": min(self.samples) if self.samples else float("nan"),
                "max": max(self.samples) if self.samples else float("nan")}

    def snapshot(self):
        return self.summary()


class NullInstrument:
    """Accepts every instrument operation and records nothing (the no-op
    sink a disabled registry hands out)."""

    name = "<null>"
    value = 0
    high = None
    low = None
    count = 0
    samples: List[float] = []
    mean = float("nan")

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def percentile(self, p: float) -> float:
        return float("nan")

    def summary(self) -> dict:
        return {}

    def snapshot(self):
        return None


_NULL = NullInstrument()


class MetricsRegistry:
    """Name-keyed instrument registry with injected clock.

    ``counter`` / ``gauge`` / ``histogram`` create-or-return by name (one
    instrument per name; asking for the same name with a different kind
    raises -- a name collision would silently split a metric).  ``now()``
    reads the injected monotonic clock; every timestamp the telemetry
    layer stores comes from here so tests can drive a fake clock.
    """

    def __init__(self, enabled: bool = True, clock=time.monotonic):
        self.enabled = enabled
        self.clock = clock
        self._instruments: Dict[str, object] = {}

    def now(self) -> float:
        return self.clock()

    # ------------------------------------------------------------------
    def _get(self, name: str, kind):
        if not self.enabled:
            return _NULL
        inst = self._instruments.get(name)
        if inst is None:
            inst = kind(name)
            self._instruments[name] = inst
        elif not isinstance(inst, kind):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(inst).__name__}, requested {kind.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def get(self, name: str) -> Optional[object]:
        """Registered instrument by name, or None (never creates)."""
        return self._instruments.get(name)

    def snapshot(self) -> dict:
        """``{name: value-or-summary}`` for every registered instrument
        (empty when disabled: a disabled registry records nothing)."""
        return {name: inst.snapshot()
                for name, inst in sorted(self._instruments.items())}


class CounterDictView(MutableMapping):
    """Dict-shaped live view over a fixed set of registry counters.

    The back-compat shim for code that treated ``scheduler.stats`` /
    ``engine.stats`` as a plain dict: reads come straight from the typed
    :class:`Counter` instruments, writes (including ``view[k] += 1``,
    which is a read-then-write) land on them.  The key set is fixed at
    construction -- a typo'd key raises instead of silently creating a
    new stat.
    """

    def __init__(self, registry: MetricsRegistry, prefix: str, keys):
        self._counters = {k: registry.counter(prefix + k) for k in keys}

    def __getitem__(self, k):
        return self._counters[k].value

    def __setitem__(self, k, v):
        self._counters[k].set(v)

    def __delitem__(self, k):
        raise TypeError("stats view has a fixed key set")

    def __iter__(self):
        return iter(self._counters)

    def __len__(self):
        return len(self._counters)

    def __repr__(self):
        return repr(dict(self))
