"""The telemetry facade the serving engines thread through.

One :class:`Telemetry` object per engine bundles the pieces:

* ``core`` -- an **always-on** mini registry holding the typed counters
  behind the back-compat ``stats`` views (``sched/admitted`` etc.).
  These are functional engine state, not optional diagnostics: they cost
  what the ad-hoc dict they replaced cost, so the telemetry knob does
  not gate them.
* ``metrics`` / ``trace`` / ``sparsity`` -- the knob-gated instruments:
  lifecycle histograms, Chrome trace spans, SPLS gauges.  With
  ``enabled=False`` these are no-op sinks and record **nothing** (the
  test suite pins an empty snapshot and an empty trace after a full
  serving run).
* ``requests`` -- per-request lifecycle records (submit / admit / first
  token / per-token cadence / preemptions / outcome) that the report
  builder aggregates into TTFT/TPOT percentiles and
  preemption/requeue rates.

Every timestamp comes from the injected monotonic clock via
``Telemetry.now()`` -- host-side only, after device readback; nothing
here is ever traced by jit.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from .metrics import MetricsRegistry
from .sparsity import SparsityInstruments
from .trace import TraceRecorder

__all__ = ["RequestRecord", "Telemetry"]


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle timestamps of one request (monotonic-clock seconds)."""

    rid: int
    prompt_len: int
    submit_ts: float
    admit_ts: Optional[float] = None     # first admission
    first_token_ts: Optional[float] = None
    last_token_ts: Optional[float] = None
    end_ts: Optional[float] = None
    n_tokens: int = 0
    n_preempts: int = 0
    outcome: Optional[str] = None        # "retired" | "aborted"

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_ts is None:
            return None
        return self.first_token_ts - self.submit_ts

    @property
    def tpot_s(self) -> Optional[float]:
        """Mean time per output token after the first (None with < 2
        tokens)."""
        if self.n_tokens < 2 or self.last_token_ts is None \
                or self.first_token_ts is None:
            return None
        return (self.last_token_ts - self.first_token_ts) \
            / (self.n_tokens - 1)


class Telemetry:
    def __init__(self, enabled: bool = True, clock=time.monotonic):
        self.enabled = enabled
        self.core = MetricsRegistry(enabled=True, clock=clock)
        self.metrics = MetricsRegistry(enabled=enabled, clock=clock)
        self.trace = TraceRecorder(enabled=enabled)
        self.sparsity = SparsityInstruments(self.metrics)
        self.requests: Dict[int, RequestRecord] = {}
        self.started_ts = clock()

    def now(self) -> float:
        return self.core.now()

    # -- request lifecycle ---------------------------------------------
    def request_submitted(self, rid: int, prompt_len: int) -> None:
        if not self.enabled:
            return
        ts = self.now()
        self.requests[rid] = RequestRecord(rid=rid, prompt_len=prompt_len,
                                           submit_ts=ts)
        tid = self.trace.track_for(rid)
        self.trace.begin("request", ts, tid,
                         args={"rid": rid, "prompt_len": prompt_len})
        self.trace.begin("queued", ts, tid)
        self.metrics.counter("requests/submitted").inc()

    def request_admitted(self, rid: int) -> None:
        if not self.enabled:
            return
        ts = self.now()
        rec = self.requests.get(rid)
        if rec is not None and rec.admit_ts is None:
            rec.admit_ts = ts
        self.trace.end("queued", ts, self.trace.track_for(rid))
        self.metrics.counter("requests/admitted").inc()

    def _unwind(self, tid: int, ts: float) -> None:
        """Close every span open on a track above the root "request"
        span -- preemption and abort can strike mid-phase, and B/E
        pairing must survive whatever phase the request was torn out
        of."""
        stack = self.trace.open_spans(tid)
        while stack and stack[-1] != "request":
            self.trace.end(stack.pop(), ts, tid)

    def request_preempted(self, rid: int) -> None:
        """Preemption-by-eviction: the request re-queues front-of-line,
        so one preemption is one requeue."""
        if not self.enabled:
            return
        ts = self.now()
        rec = self.requests.get(rid)
        if rec is not None:
            rec.n_preempts += 1
        tid = self.trace.track_for(rid)
        self._unwind(tid, ts)   # may be mid-prefill (grow_to self-preempt)
        self.trace.instant("preempt", ts, tid)
        self.trace.begin("queued", ts, tid)   # back in the waiting line
        self.metrics.counter("requests/preemptions").inc()
        self.metrics.counter("requests/requeues").inc()

    def _finish(self, rid: int, outcome: str) -> None:
        ts = self.now()
        rec = self.requests.get(rid)
        if rec is not None and rec.outcome is None:
            rec.end_ts = ts
            rec.outcome = outcome
            tpot = rec.tpot_s
            if tpot is not None:
                self.metrics.histogram("latency/tpot_s").observe(tpot)
            self.metrics.histogram("latency/e2e_s").observe(
                ts - rec.submit_ts)
        tid = self.trace.track_for(rid)
        self._unwind(tid, ts)   # queued / mid-prefill spans, if any
        if outcome == "aborted":
            self.trace.instant("abort", ts, tid)
        self.trace.end("request", ts, tid, args={"outcome": outcome})
        self.metrics.counter(f"requests/{outcome}").inc()

    def request_retired(self, rid: int) -> None:
        if self.enabled:
            self._finish(rid, "retired")

    def request_aborted(self, rid: int) -> None:
        if self.enabled:
            self._finish(rid, "aborted")

    # -- tokens --------------------------------------------------------
    def first_token(self, rid: int) -> None:
        if not self.enabled:
            return
        ts = self.now()
        rec = self.requests.get(rid)
        if rec is not None:
            if rec.first_token_ts is None:
                ttft = ts - rec.submit_ts
                self.metrics.histogram("latency/ttft_s").observe(ttft)
                self.trace.instant("first_token", ts,
                                   self.trace.track_for(rid))
            rec.first_token_ts = rec.first_token_ts or ts
            rec.last_token_ts = ts
            rec.n_tokens += 1
        self.metrics.counter("tokens/emitted").inc()

    def tokens_decoded(self, rids: List[int]) -> None:
        """One batched decode tick produced one token per rid (single
        clock read for the whole batch)."""
        if not self.enabled or not rids:
            return
        ts = self.now()
        for rid in rids:
            rec = self.requests.get(rid)
            if rec is None:
                continue
            if rec.first_token_ts is None:
                rec.first_token_ts = ts
                self.metrics.histogram("latency/ttft_s").observe(
                    ts - rec.submit_ts)
                self.trace.instant("first_token", ts,
                                   self.trace.track_for(rid))
            rec.last_token_ts = ts
            rec.n_tokens += 1
        self.metrics.counter("tokens/emitted").inc(len(rids))

    # -- engine phases -------------------------------------------------
    def span_begin(self, name: str, rid: Optional[int] = None,
                   args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        tid = 0 if rid is None else self.trace.track_for(rid)
        self.trace.begin(name, self.now(), tid, args=args)

    def span_end(self, name: str, rid: Optional[int] = None,
                 args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        tid = 0 if rid is None else self.trace.track_for(rid)
        self.trace.end(name, self.now(), tid, args=args)
