"""Aggregate telemetry into the schema-versioned ``BENCH_serving.json``.

The report is the per-PR perf trajectory artifact ROADMAP item 5 asks
for: TTFT/TPOT p50/p99, goodput, preemption/requeue rates, per-component
``flops_saved_*``, pool/pred-cache bytes, and capacity-controller
occupancy -- everything the prose claims of PRs 2-5 measured, now
machine-readable.  ``benchmarks/run.py`` and
``benchmarks/bench_throughput.py`` write it to the repo root on every
run; ``examples/serve_batch.py --bench-json`` writes one per serving
run; CI validates it with this module's CLI:

    python -m repro.observability.report BENCH_serving.json \
        [--require-nonzero-flops]

Schema (version 1) -- required keys checked by :func:`validate_report`:

* ``schema_version``: int
* ``latency.ttft_ms`` / ``latency.tpot_ms``: ``{p50, p99, mean, n}``
* ``requests``: ``{submitted, retired, aborted, preemptions, requeues,
  preemption_rate, requeue_rate}``
* ``throughput``: ``{tokens, wall_s, tok_s, goodput_tok_s}``
* ``sparsity.flops_saved_{qkv,kv,attn,ffn}_pct``: floats

Extra keys (``pool``, ``capacity``, ``counters``, benchmark ``rows``)
are allowed and ignored by validation.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

__all__ = ["SCHEMA_VERSION", "latency_ms", "serving_report",
           "validate_report", "write_report"]

SCHEMA_VERSION = 1

_FLOPS_COMPONENTS = ("qkv", "kv", "attn", "ffn")


def latency_ms(hist) -> dict:
    """p50/p99/mean summary of a seconds histogram, in milliseconds."""
    if hist is None or getattr(hist, "count", 0) == 0:
        return {"p50": None, "p99": None, "mean": None, "n": 0}
    return {"p50": hist.percentile(50.0) * 1e3,
            "p99": hist.percentile(99.0) * 1e3,
            "mean": hist.mean * 1e3, "n": hist.count}


def serving_report(engine, wall_s: Optional[float] = None,
                   extra: Optional[dict] = None) -> dict:
    """Build the schema-v1 report from a drained serving engine.

    ``wall_s`` overrides the wall-clock denominator (defaults to time
    since the engine's telemetry started); ``extra`` is merged in at the
    top level (benchmark rows, workload descriptors).
    """
    tel = engine.telemetry
    m = tel.metrics
    if wall_s is None:
        wall_s = max(tel.now() - tel.started_ts, 1e-9)

    recs = list(tel.requests.values())
    retired = [r for r in recs if r.outcome == "retired"]
    aborted = [r for r in recs if r.outcome == "aborted"]
    tokens = sum(r.n_tokens for r in recs)
    good_tokens = sum(r.n_tokens for r in retired)
    preempts = sum(r.n_preempts for r in recs)
    admits = max(len([r for r in recs if r.admit_ts is not None]), 1)

    stats = engine.stats
    saved = stats.get("flops_saved_pct", {})
    sparsity = {f"flops_saved_{c}_pct": float(saved.get(c, 0.0))
                for c in _FLOPS_COMPONENTS}
    kept = m.get("spls/kept_ratio")
    if kept is not None and kept.count:
        sparsity["kept_ratio"] = kept.summary()
    for name in ("spls/horizon_finalized_cols",
                 "spls/horizon_kv_capacity_drops"):
        inst = m.get(name)
        if inst is not None:
            sparsity[name.split("/", 1)[1]] = inst.value

    report = {
        "schema_version": SCHEMA_VERSION,
        "engine": {
            "kind": type(engine).__name__,
            "compute_backend": stats.get("compute_backend"),
            "telemetry": tel.enabled,
        },
        "requests": {
            "submitted": len(recs),
            "retired": len(retired),
            "aborted": len(aborted),
            "preemptions": preempts,
            "requeues": preempts,       # preemption-by-eviction requeues
            "preemption_rate": preempts / admits,
            "requeue_rate": preempts / admits,
        },
        "latency": {
            "ttft_ms": latency_ms(m.get("latency/ttft_s")),
            "tpot_ms": latency_ms(m.get("latency/tpot_s")),
            "e2e_ms": latency_ms(m.get("latency/e2e_s")),
        },
        "throughput": {
            "tokens": tokens,
            "wall_s": wall_s,
            "tok_s": tokens / wall_s,
            # goodput: tokens of requests that actually retired (aborted
            # work is wasted throughput)
            "goodput_tok_s": good_tokens / wall_s,
        },
        "sparsity": sparsity,
        "counters": m.snapshot(),
    }

    pool = getattr(engine, "pool", None)
    if pool is not None:
        pool_info = {"n_pages": pool.n_pages, "page_size": pool.page_size,
                     "peak_pages": pool.peak_in_use,
                     "pages_in_use": pool.pages_in_use,
                     "guard_trips": pool.guard_trips}
        for name in ("pool/kv_bytes", "pool/pred_cache_bytes"):
            g = m.get(name)
            if g is not None:
                pool_info[name.split("/", 1)[1]] = g.value
        report["pool"] = pool_info
    caps = {}
    for key in ("capacity_q", "capacity_ffn", "capacity_kv"):
        if key in stats:
            caps[key[len("capacity_"):]] = stats[key]
    if caps:
        report["capacity"] = caps
    if extra:
        report.update(extra)
    return report


def validate_report(report: dict,
                    require_nonzero_flops: bool = False) -> None:
    """Raise ValueError naming every schema violation at once."""
    problems = []

    def need(path, typ=None):
        node = report
        for part in path.split("."):
            if not isinstance(node, dict) or part not in node:
                problems.append(f"missing key: {path}")
                return None
            node = node[part]
        if typ is not None and not isinstance(node, typ):
            problems.append(
                f"{path}: expected {typ}, got {type(node).__name__}")
        return node

    ver = need("schema_version", int)
    if ver is not None and ver != SCHEMA_VERSION:
        problems.append(f"schema_version {ver} != {SCHEMA_VERSION}")
    for lat in ("ttft_ms", "tpot_ms"):
        for q in ("p50", "p99", "mean", "n"):
            need(f"latency.{lat}.{q}")
    for k in ("submitted", "retired", "aborted", "preemptions",
              "requeues"):
        need(f"requests.{k}", int)
    for k in ("preemption_rate", "requeue_rate"):
        need(f"requests.{k}", (int, float))
    for k in ("tokens", "wall_s", "tok_s", "goodput_tok_s"):
        need(f"throughput.{k}", (int, float))
    for c in _FLOPS_COMPONENTS:
        v = need(f"sparsity.flops_saved_{c}_pct", (int, float))
        if require_nonzero_flops and v is not None and not v > 0.0:
            problems.append(
                f"sparsity.flops_saved_{c}_pct must be > 0, got {v}")
    if problems:
        raise ValueError("invalid BENCH_serving.json:\n  "
                         + "\n  ".join(problems))


def write_report(path: str, report: dict) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True, default=str)
        f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate a BENCH_serving.json against schema "
                    f"version {SCHEMA_VERSION}")
    ap.add_argument("path")
    ap.add_argument("--require-nonzero-flops", action="store_true",
                    help="additionally require every "
                         "sparsity.flops_saved_*_pct > 0")
    args = ap.parse_args(argv)
    with open(args.path) as f:
        report = json.load(f)
    try:
        validate_report(report,
                        require_nonzero_flops=args.require_nonzero_flops)
    except ValueError as e:
        print(e, file=sys.stderr)
        return 1
    lat = report["latency"]
    print(f"{args.path}: valid (schema v{report['schema_version']}); "
          f"ttft_p50={lat['ttft_ms']['p50']}ms "
          f"tpot_p50={lat['tpot_ms']['p50']}ms "
          f"tok_s={report['throughput']['tok_s']:.1f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
