"""Serving telemetry subsystem.

Host-side, low-overhead observability for the paged SPLS serving stack:
typed metrics (:mod:`metrics`), per-request lifecycle tracing as Chrome
trace events (:mod:`trace`), SPLS sparsity instruments
(:mod:`sparsity`), the engine-facing facade (:mod:`telemetry`), and the
``BENCH_serving.json`` report builder/validator (:mod:`report`).  See
``serving/README.md`` ("Observability") for the instrument table and
how to open traces in Perfetto.
"""

from .metrics import (Counter, CounterDictView, Gauge, Histogram,
                      MetricsRegistry, NullInstrument, percentile)
from .trace import ENGINE_TRACK, TraceRecorder
from .sparsity import SparsityInstruments, tree_bytes
from .telemetry import RequestRecord, Telemetry
from .report import (SCHEMA_VERSION, latency_ms, serving_report,
                     validate_report, write_report)

__all__ = [
    "Counter", "CounterDictView", "Gauge", "Histogram", "MetricsRegistry",
    "NullInstrument", "percentile", "ENGINE_TRACK", "TraceRecorder",
    "SparsityInstruments", "tree_bytes",
    "RequestRecord", "Telemetry",
    "SCHEMA_VERSION", "latency_ms", "serving_report", "validate_report",
    "write_report",
]
