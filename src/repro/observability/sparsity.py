"""SPLS-specific serving instruments.

What ESACT's sparsity pipeline should be able to show about itself at
runtime (cf. AccelTran's per-component realized-vs-predicted sparsity
counters): realized kept-column ratios vs the scheduler's EMA estimate,
vote-horizon finalization counts, capacity-bucket occupancy and
overflow-fallback rates per :class:`~repro.sparse_compute.capacity.
CapacityController`, and the byte/occupancy gauges of the page pool and
the int8 predictor cache.

Everything here is a thin naming layer over the
:class:`~repro.observability.metrics.MetricsRegistry` -- one place owns
the instrument names so the engine, the report builder, and the tests
agree on them.  All methods are host-side and cheap; with a disabled
registry every call lands on the shared null instrument.

Note on "per-layer": serving's prune decision is *layer-shared* by
design -- the layer-0 cross-head vote decides a page slot that every
layer uses (SpAtten-style; see ``serving/README.md``) -- so the kept
ratio is one number per request plus the per-head agreement the vote
aggregates, not a per-layer family.
"""

from __future__ import annotations

from .metrics import MetricsRegistry

__all__ = ["SparsityInstruments", "tree_bytes"]


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays (metadata only, no device
    sync)."""
    import jax

    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


class SparsityInstruments:
    def __init__(self, registry: MetricsRegistry):
        self.registry = registry

    # -- prune vote ----------------------------------------------------
    def note_prune(self, prompt_len: int, kept: int) -> None:
        """One request's finalized page-prune outcome."""
        r = self.registry
        if prompt_len > 0:
            r.histogram("spls/kept_ratio").observe(kept / prompt_len)
        r.counter("spls/columns_seen").inc(prompt_len)
        r.counter("spls/columns_kept").inc(kept)

    def note_votes(self, head_votes) -> None:
        """Per-head agreement at vote finalization: ``head_votes`` is the
        (H, S) accumulated keep-vote matrix; records the fraction of
        prompt columns each head wanted kept."""
        import numpy as np

        hv = np.asarray(head_votes)
        if hv.size == 0:
            return
        hist = self.registry.histogram("spls/head_keep_frac")
        for frac in hv.mean(axis=1):
            hist.observe(float(frac))

    # -- horizon-finalized votes (core.planner) ------------------------
    def note_horizon(self, finalized: int, kv_capacity_drops: int = 0
                     ) -> None:
        r = self.registry
        r.counter("spls/horizon_finalized_cols").inc(finalized)
        if kv_capacity_drops:
            r.counter("spls/horizon_kv_capacity_drops").inc(
                kv_capacity_drops)

    # -- capacity controllers (sparse_compute.capacity) ----------------
    def note_capacity(self, kind: str, capacity: int, observed: int,
                      overflowed: bool) -> None:
        """One packed chunk's capacity outcome for controller ``kind``
        (``q`` / ``ffn`` / ``kv``): the bucket served, the critical-row
        count observed, and whether the chunk overflowed into the
        window-leader fallback."""
        r = self.registry
        r.gauge(f"capacity/{kind}_bucket").set(capacity)
        r.histogram(f"capacity/{kind}_critical_rows").observe(observed)
        if capacity > 0:
            r.histogram(f"capacity/{kind}_occupancy").observe(
                min(observed, capacity) / capacity)
        r.counter(f"capacity/{kind}_chunks").inc()
        if overflowed:
            r.counter(f"capacity/{kind}_overflows").inc()

    # -- page pool / predictor cache -----------------------------------
    def observe_pool(self, pool) -> None:
        """Pool occupancy gauges (the gauge keeps the high-watermark) and
        the double-free/foreign-free guard-trip counter."""
        r = self.registry
        r.gauge("pool/pages_in_use").set(pool.pages_in_use)
        r.gauge("pool/free_pages").set(pool.free_pages)
        if pool.capacity > 0:
            r.gauge("pool/utilization").set(
                pool.pages_in_use / pool.capacity)
        r.counter("pool/guard_trips").set(pool.guard_trips)

    def note_pool_bytes(self, kv_bytes: int, pred_bytes: int = 0) -> None:
        r = self.registry
        r.gauge("pool/kv_bytes").set(kv_bytes)
        r.gauge("pool/pred_cache_bytes").set(pred_bytes)
