"""Per-request lifecycle tracing as Chrome trace events (Perfetto).

The serving engine opens a span per request phase -- ``queued`` (submit
to admit), one ``prefill_chunk`` per streamed chunk,
``prune_compact`` around the end-of-prefill vote/compaction, one
engine-scope ``decode_tick`` per batched decode -- and marks point
events (``first_token``, ``preempt``, ``abort``) as instants.  Export is
the Chrome trace-event JSON array format: load the file at
https://ui.perfetto.dev (or chrome://tracing) and each request renders
as its own track (``tid`` = request id; ``tid 0`` is the engine track).

Timestamps come from the caller (the registry's injected monotonic
clock), converted to the format's microsecond unit at export.  Spans are
**B/E pairs**: ``begin``/``end`` must nest per track, which
:func:`TraceRecorder.validate` checks -- the test suite runs it on real
engine traces.

A ``TraceRecorder(enabled=False)`` drops everything (records nothing);
``max_events`` bounds memory on long runs, with the overflow counted in
``dropped`` instead of silently truncating.
"""

from __future__ import annotations

import json
from typing import List, Optional

__all__ = ["TraceRecorder", "ENGINE_TRACK"]

# tid of the engine-scope track (requests use tid = rid + 1 so rid 0
# does not collide with the engine track)
ENGINE_TRACK = 0


class TraceRecorder:
    def __init__(self, enabled: bool = True, pid: int = 1,
                 max_events: int = 200_000):
        self.enabled = enabled
        self.pid = pid
        self.max_events = max_events
        self.events: List[dict] = []
        self.dropped = 0
        self._stacks: dict = {}   # (pid, tid) -> [open span names]

    # ------------------------------------------------------------------
    def _emit(self, ev: dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def open_spans(self, tid: int) -> List[str]:
        """Names of currently open spans on a track, outermost first
        (the preemption/abort paths unwind these so B/E pairing stays
        valid whatever phase the request was torn out of)."""
        return list(self._stacks.get((self.pid, tid), []))

    @staticmethod
    def track_for(rid: int) -> int:
        return rid + 1

    def begin(self, name: str, ts: float, tid: int = ENGINE_TRACK,
              args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        ev = {"ph": "B", "name": name, "ts": ts, "pid": self.pid,
              "tid": tid}
        if args:
            ev["args"] = args
        self._stacks.setdefault((self.pid, tid), []).append(name)
        self._emit(ev)

    def end(self, name: str, ts: float, tid: int = ENGINE_TRACK,
            args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        ev = {"ph": "E", "name": name, "ts": ts, "pid": self.pid,
              "tid": tid}
        if args:
            ev["args"] = args
        stack = self._stacks.get((self.pid, tid))
        if stack and stack[-1] == name:
            stack.pop()
        self._emit(ev)

    def instant(self, name: str, ts: float, tid: int = ENGINE_TRACK,
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        ev = {"ph": "i", "name": name, "ts": ts, "pid": self.pid,
              "tid": tid, "s": "t"}
        if args:
            ev["args"] = args
        self._emit(ev)

    # ------------------------------------------------------------------
    def validate(self) -> None:
        """Raise ValueError unless every B has a matching E, properly
        nested per (pid, tid) track, with non-decreasing timestamps."""
        stacks: dict = {}
        last_ts: dict = {}
        for ev in self.events:
            key = (ev["pid"], ev["tid"])
            if ev["ts"] < last_ts.get(key, float("-inf")):
                raise ValueError(
                    f"timestamps regress on track {key}: {ev}")
            last_ts[key] = ev["ts"]
            if ev["ph"] == "B":
                stacks.setdefault(key, []).append(ev["name"])
            elif ev["ph"] == "E":
                stack = stacks.get(key)
                if not stack:
                    raise ValueError(f"E without open B on {key}: {ev}")
                top = stack.pop()
                if top != ev["name"]:
                    raise ValueError(
                        f"mismatched span nesting on {key}: "
                        f"E {ev['name']!r} closes B {top!r}")
        open_spans = {k: v for k, v in stacks.items() if v}
        if open_spans:
            raise ValueError(f"unclosed spans: {open_spans}")

    def to_chrome_trace(self, time_scale: float = 1e6) -> dict:
        """Chrome trace JSON object.  ``time_scale`` converts the
        recorder's timestamp unit (seconds, from the monotonic clock) to
        the format's microseconds."""
        events = [{**ev, "ts": ev["ts"] * time_scale} for ev in self.events]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path: str, time_scale: float = 1e6) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(time_scale), f)
