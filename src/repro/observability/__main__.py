"""``python -m repro.observability <BENCH_serving.json>`` -- validate a
serving report against the current schema (delegates to
:mod:`repro.observability.report`)."""

import sys

from .report import main

if __name__ == "__main__":
    sys.exit(main())
