"""Sharded checkpointing with manifest + atomic commit (no orbax).

Layout of a checkpoint directory::

    step_000123/
      MANIFEST.json     # pytree structure, shapes, dtypes, step, data step
      arrays/<leaf-id>.npy
      COMMITTED         # written last -- a dir without it is garbage

Restart safety comes from three properties:
  * atomic commit marker -- partially written checkpoints are never loaded;
  * the data-pipeline step is stored, so the deterministic pipeline resumes
    exactly where it left off (no sample is seen twice or skipped);
  * save/restore go through ``jax.device_get``/``device_put`` with the
    caller-provided shardings, so a checkpoint written on one mesh can be
    restored onto a different mesh (elastic re-shard on restart).

At 1000+ nodes each host would write only its addressable shards; this
single-process implementation writes full arrays but keeps the manifest
format host-sharded-ready (leaf ids are stable pytree paths).
"""

from __future__ import annotations

import json
import shutil
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "cleanup_old"]


def _leaf_id(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return ".".join(parts) or "root"


def save_checkpoint(base: str, step: int, tree: Any,
                    data_step: Optional[int] = None,
                    keep: int = 3) -> str:
    """Write ``tree`` atomically under ``base/step_{step:09d}``."""
    base_p = Path(base)
    final = base_p / f"step_{step:09d}"
    tmp = base_p / f".tmp_step_{step:09d}_{int(time.time() * 1e6)}"
    (tmp / "arrays").mkdir(parents=True, exist_ok=True)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "data_step": data_step, "leaves": []}
    for path, leaf in leaves:
        lid = _leaf_id(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / "arrays" / f"{lid}.npy", arr)
        manifest["leaves"].append(
            {"id": lid, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
    (tmp / "COMMITTED").write_text(str(time.time()))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    cleanup_old(base, keep)
    return str(final)


def latest_step(base: str) -> Optional[int]:
    """Newest *committed* checkpoint step, or None."""
    base_p = Path(base)
    if not base_p.exists():
        return None
    steps = []
    for d in base_p.iterdir():
        if d.name.startswith("step_") and (d / "COMMITTED").exists():
            steps.append(int(d.name[5:]))
    return max(steps) if steps else None


def restore_checkpoint(base: str, tree_like: Any, step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[Any, int, Optional[int]]:
    """Restore into the structure of ``tree_like``.

    Returns (tree, step, data_step).  With ``shardings`` given, each leaf is
    device_put with its target sharding -- this is the elastic-restart path:
    the mesh may differ from the one that wrote the checkpoint.
    """
    if step is None:
        step = latest_step(base)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {base}")
    d = Path(base) / f"step_{step:09d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (path, like), shd in zip(flat, shard_flat):
        lid = _leaf_id(path)
        arr = np.load(d / "arrays" / f"{lid}.npy")
        if hasattr(like, "dtype"):
            arr = arr.astype(like.dtype)
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), leaves)
    return tree, manifest["step"], manifest.get("data_step")


def cleanup_old(base: str, keep: int) -> None:
    base_p = Path(base)
    if not base_p.exists():
        return
    steps = sorted(
        int(d.name[5:]) for d in base_p.iterdir()
        if d.name.startswith("step_") and (d / "COMMITTED").exists())
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(base_p / f"step_{s:09d}", ignore_errors=True)
    # remove stale tmp dirs (crashed writes)
    for d in base_p.iterdir():
        if d.name.startswith(".tmp_step_"):
            shutil.rmtree(d, ignore_errors=True)
