"""Sharded atomic checkpointing (manifest + COMMITTED marker)."""

from .ckpt import cleanup_old, latest_step, restore_checkpoint, save_checkpoint
