"""Optimizers + schedules + distributed-optimization tricks (pure JAX)."""

from .adamw import (AdamWConfig, OptState, adamw_init, adamw_update,
                    clip_by_global_norm, global_norm)
from .schedules import constant, warmup_cosine
from .grad_compress import (CompressionState, compress, compress_init,
                            compressed_mean, decompress)
