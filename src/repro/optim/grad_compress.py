"""Gradient compression for the data-parallel all-reduce.

At 1000+ nodes the cross-pod (DCI) gradient all-reduce is the scaling
bottleneck; int8 block-quantized gradients with error feedback cut those
bytes 4x while keeping convergence (the residual re-injects the rounding
error next step).  Compression happens *before* the pjit-visible reduction:
the train step all-reduces the quantized values (int8 tensors summed in
int32/float32) and the decode rescales -- XLA sees 1-byte collective
operands, which is exactly what the collective roofline term rewards.

This module is numerics-only (quantize / dequantize / error feedback);
wiring into the step is in ``repro.runtime.trainer``.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionState", "compress_init", "compress", "decompress",
           "compressed_mean"]

_BLOCK = 256  # quantization block (per-block scale)


class CompressionState(NamedTuple):
    residual: Any  # error-feedback buffer, same structure as grads


def compress_init(grads_like: Any) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                              grads_like))


def _blockify(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK), pad


def compress(g: jax.Array, residual: Optional[jax.Array] = None):
    """float grad -> (int8 codes, f32 per-block scales, new residual)."""
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    blocks, _ = _blockify(g32)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:g.size].reshape(g.shape)
    new_residual = g32 - deq
    return q, scale, new_residual


def decompress(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    deq = q.astype(jnp.float32) * scale
    size = 1
    for s in shape:
        size *= s
    return deq.reshape(-1)[:size].reshape(shape)


def compressed_mean(g: jax.Array, axis_name: str,
                    residual: Optional[jax.Array] = None):
    """Error-feedback int8 psum-mean over a shard_map axis.

    Returns (mean_grad, new_residual).  Summing int8 codes directly would
    overflow, so the codes are widened to f32 *after* quantization -- the
    collective still moves 1/4 of the bf16 bytes when XLA keeps the operand
    int8 (we psum the int8 tensor widened lazily; see the lowered HLO check
    in tests).
    """
    q, scale, new_res = compress(g, residual)
    n = jax.lax.psum(1, axis_name)
    summed = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
    return (summed / n).reshape(-1)[:g.size].reshape(g.shape), new_res
