"""AdamW in pure JAX (no optax): functional init/update with global-norm
clipping, decoupled weight decay, and dtype-configurable moments.

Moments inherit each parameter's sharding automatically (tree_map of
elementwise ops), so the optimizer adds no collectives beyond the gradient
all-reduce that pjit already inserts.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Optional[str] = None  # None -> match param dtype


class OptState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


def _moment_dtype(cfg: AdamWConfig, p: jax.Array):
    if cfg.moment_dtype is None:
        return p.dtype
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]


def adamw_init(cfg: AdamWConfig, params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, _moment_dtype(cfg, p))
    return OptState(count=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm


def adamw_update(cfg: AdamWConfig, grads: Any, state: OptState, params: Any,
                 lr: jax.Array) -> Tuple[Any, OptState, dict]:
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    count = state.count + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * cfg.b1 + g32 * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g32) * (1 - cfg.b2)
        mhat = m32 / b1c
        vhat = v32 / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    # three passes so pytree tuples in params (period blocks) stay pytrees;
    # XLA CSEs the shared moment math across them.
    new_params = jax.tree.map(lambda g, m, v, p: upd(g, m, v, p)[0],
                              grads, state.mu, state.nu, params)
    new_mu = jax.tree.map(lambda g, m, v, p: upd(g, m, v, p)[1],
                          grads, state.mu, state.nu, params)
    new_nu = jax.tree.map(lambda g, m, v, p: upd(g, m, v, p)[2],
                          grads, state.mu, state.nu, params)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(count, new_mu, new_nu), metrics
