"""Transformer / Mamba blocks with first-class SPLS integration.

A block = (pre-norm -> mixer -> residual) + optional (pre-norm -> FFN ->
residual), with optional gemma2-style post-norms.  When SPLS is enabled and
the mixer is attention, the block runs the paper's pipeline: the plan is
built from the *normalized block input* and the attention projection weights
-- i.e. prediction happens before QKV generation, exactly as in Fig. 5(a) --
then attention and the FFN execute sparsely under the plan.  All plan
*construction* lives in the unified planner (:mod:`repro.core.planner`);
this module selects a driver (``plan_mode``) and executes under the plan.

SPLS applicability (DESIGN.md §Arch-applicability): attention-free (mamba)
blocks have no PAM to predict, so SPLS does not apply to them; in hybrid
archs the attention blocks still use it.  FFN sparsity requires per-head
leaders, so it also only triggers in attention blocks.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.sparse_exec import spls_ffn, spls_ffn_packed
# All SPLS plan construction lives in the unified planner
# (repro.core.planner); these names are re-exported for compatibility --
# this module only *selects* a driver and executes under the plan.
from repro.core.planner import (build_block_plan, build_block_plan_chunked,
                                build_block_plan_progressive,
                                progressive_plan_blocks)
from .attention import (KVCache, attention_decode, attention_forward,
                        init_attention, init_kv_cache)
from .common import rms_norm
from .mamba import (MambaCache, init_mamba, init_mamba_cache, mamba_decode,
                    mamba_forward)
from .moe import ffn_forward, init_ffn

__all__ = ["init_block", "block_forward", "block_decode", "init_block_cache",
           "build_block_plan", "build_block_plan_chunked",
           "build_block_plan_progressive", "progressive_plan_blocks"]


def init_block(cfg: ArchConfig, blk: BlockCfg, key: jax.Array, dtype) -> dict:
    ks = jax.random.split(key, 2)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if blk.mixer == "attn":
        p["attn"] = init_attention(cfg, ks[0], dtype)
    else:
        p["mamba"] = init_mamba(cfg, ks[0], dtype)
    if blk.has_ffn:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = init_ffn(cfg, blk.use_moe, ks[1], dtype)
    if cfg.use_post_norm:
        p["post_ln1"] = jnp.zeros((cfg.d_model,), dtype)
        if blk.has_ffn:
            p["post_ln2"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_block_cache(cfg: ArchConfig, blk: BlockCfg, batch: int, max_len: int,
                     dtype):
    if blk.mixer == "attn":
        return init_kv_cache(cfg, batch, max_len, dtype)
    return init_mamba_cache(cfg, batch, dtype)


_SPLS_CHUNK_THRESHOLD = 8192


def _capacities(cfg: ArchConfig, L: int) -> Tuple[Optional[int], Optional[int]]:
    s = cfg.spls
    qc = None if s.q_capacity_ratio >= 1.0 else max(
        s.window, math.ceil(s.q_capacity_ratio * L))
    kc = None if s.kv_capacity_ratio >= 1.0 else max(
        s.window, math.ceil(s.kv_capacity_ratio * L))
    return qc, kc


def block_forward(cfg: ArchConfig, blk: BlockCfg, p: dict, x: jax.Array,
                  cache_len: Optional[int] = None,
                  attn_backend: Optional[str] = None,
                  plan_mode: str = "auto"):
    """Full-sequence block.  x: (B, L, D).

    With ``cache_len`` (prefill) also returns the block's decode cache.
    ``attn_backend`` overrides ``cfg.attn_backend`` for the mixer (see
    :mod:`repro.models.attn_backend`).  ``plan_mode="progressive"`` builds
    the SPLS plan with :func:`build_block_plan_progressive` (streaming-
    reproducible numerics -- the serving engines use this so chunked and
    full prefills agree bit-for-bit); ``"auto"`` keeps the exact-top-k
    builder, switching to the ChunkedPlan path at long L.
    """
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    plan, cache = None, None
    if blk.mixer == "attn":
        from .attention import head_shard_mode
        # padded head mode (no divisible factorization) runs dense: the
        # SPLS plan layout would need garbage-head vote filtering -- noted
        # in DESIGN.md §Arch-applicability.
        if head_shard_mode(cfg) != "padded":
            if plan_mode == "progressive":
                plan = build_block_plan_progressive(cfg, p, xn)
            elif cfg.spls.enabled and x.shape[1] >= _SPLS_CHUNK_THRESHOLD:
                plan = build_block_plan_chunked(cfg, p, xn)
            else:
                plan = build_block_plan(cfg, p, xn)
        qc, kc = _capacities(cfg, x.shape[1]) if plan is not None else (None, None)
        h = attention_forward(cfg, p["attn"], xn, window=blk.window,
                              plan=plan, q_capacity=qc, kv_capacity=kc,
                              cache_len=cache_len, backend=attn_backend)
        if cache_len is not None:
            h, cache = h
    else:
        h = mamba_forward(cfg, p["mamba"], xn, want_cache=cache_len is not None)
        if cache_len is not None:
            h, cache = h
    if cfg.use_post_norm:
        h = rms_norm(h, p["post_ln1"], cfg.norm_eps)
    x = x + h

    if blk.has_ffn:
        xn2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        fn = lambda t: ffn_forward(cfg, blk.use_moe, p["ffn"], t)
        if plan is not None and cfg.spls.ffn_sparsity:
            qc, _ = _capacities(cfg, x.shape[1])
            if qc is not None:
                # capacity mode: the compute-backend axis decides how the
                # packed rows execute (repro.sparse_compute; "dense"
                # config default keeps the XLA pack/unpack closure); MoE
                # blocks keep it -- their capacity routing *is* the pack
                from repro.sparse_compute import (is_packed,
                                                  resolve_compute_backend)
                cb = resolve_compute_backend(cfg.compute_backend,
                                             sparse=True)
                if is_packed(cb) and not blk.use_moe:
                    from repro.core.sparse_exec import compact_rows
                    from repro.sparse_compute import packed_mlp
                    comp = compact_rows(plan.ffn_critical, qc,
                                        leader=plan.ffn_leader,
                                        window=cfg.spls.window)
                    h2 = packed_mlp(cfg, p["ffn"], xn2, comp, cb)
                else:
                    h2 = spls_ffn_packed(xn2, fn, plan, qc,
                                         window=cfg.spls.window)
            else:
                h2 = spls_ffn(xn2, fn, plan)
        else:
            h2 = fn(xn2)
        if cfg.use_post_norm:
            h2 = rms_norm(h2, p["post_ln2"], cfg.norm_eps)
        x = x + h2
    if cache_len is not None:
        return x, cache
    return x


def block_decode(cfg: ArchConfig, blk: BlockCfg, p: dict, x: jax.Array,
                 cache, pos: jax.Array, attn_backend: Optional[str] = None):
    """One-token decode.  x: (B, 1, D); returns (x, new_cache)."""
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    if blk.mixer == "attn":
        h, cache = attention_decode(cfg, p["attn"], xn, cache, pos,
                                    window=blk.window, backend=attn_backend)
    else:
        h, cache = mamba_decode(cfg, p["mamba"], xn, cache)
    if cfg.use_post_norm:
        h = rms_norm(h, p["post_ln1"], cfg.norm_eps)
    x = x + h
    if blk.has_ffn:
        xn2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        h2 = ffn_forward(cfg, blk.use_moe, p["ffn"], xn2)
        if cfg.use_post_norm:
            h2 = rms_norm(h2, p["post_ln2"], cfg.norm_eps)
        x = x + h2
    return x, cache
