"""Transformer / Mamba blocks with first-class SPLS integration.

A block = (pre-norm -> mixer -> residual) + optional (pre-norm -> FFN ->
residual), with optional gemma2-style post-norms.  When SPLS is enabled and
the mixer is attention, the block runs the paper's pipeline: the plan is
built from the *normalized block input* and the attention projection weights
-- i.e. prediction happens before QKV generation, exactly as in Fig. 5(a) --
then attention and the FFN execute sparsely under the plan.

SPLS applicability (DESIGN.md §Arch-applicability): attention-free (mamba)
blocks have no PAM to predict, so SPLS does not apply to them; in hybrid
archs the attention blocks still use it.  FFN sparsity requires per-head
leaders, so it also only triggers in attention blocks.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.spls import SparsityPlan, build_plan
from repro.core.sparse_exec import spls_ffn, spls_ffn_packed
from .attention import (KVCache, attention_decode, attention_forward,
                        init_attention, init_kv_cache)
from .common import rms_norm
from .mamba import (MambaCache, init_mamba, init_mamba_cache, mamba_decode,
                    mamba_forward)
from .moe import ffn_forward, init_ffn

__all__ = ["init_block", "block_forward", "block_decode", "init_block_cache",
           "build_block_plan", "build_block_plan_progressive",
           "progressive_plan_blocks"]


def init_block(cfg: ArchConfig, blk: BlockCfg, key: jax.Array, dtype) -> dict:
    ks = jax.random.split(key, 2)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if blk.mixer == "attn":
        p["attn"] = init_attention(cfg, ks[0], dtype)
    else:
        p["mamba"] = init_mamba(cfg, ks[0], dtype)
    if blk.has_ffn:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = init_ffn(cfg, blk.use_moe, ks[1], dtype)
    if cfg.use_post_norm:
        p["post_ln1"] = jnp.zeros((cfg.d_model,), dtype)
        if blk.has_ffn:
            p["post_ln2"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_block_cache(cfg: ArchConfig, blk: BlockCfg, batch: int, max_len: int,
                     dtype):
    if blk.mixer == "attn":
        return init_kv_cache(cfg, batch, max_len, dtype)
    return init_mamba_cache(cfg, batch, dtype)


def build_block_plan(cfg: ArchConfig, p: dict, xn: jax.Array
                     ) -> Optional[SparsityPlan]:
    """Run SPLS prediction on the normalized block input (before QKV gen).

    Plan tensors use the TP-friendly (B, KV, G, ...) head layout so the
    whole prediction pipeline (HLog matmuls, top-k, windowed similarity)
    shards over the same axes as the formal attention -- no resharding
    between prediction and execution.
    """
    if not cfg.spls.enabled:
        return None
    import dataclasses

    from repro.core import mfi as _mfi
    from repro.core import similarity as _sim
    from repro.core import topk as _topk
    from repro.core.predict import predict_qk
    from repro.sharding.logical import constrain as _cn

    D, KV, Dh = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    B, L, _ = xn.shape
    scfg = cfg.spls
    if scfg.causal != cfg.causal:
        scfg = dataclasses.replace(scfg, causal=cfg.causal)

    from .attention import head_shard_mode
    mode = head_shard_mode(cfg)
    wq = p["attn"]["wq"].reshape(D, KV * G * Dh)
    wk = p["attn"]["wk"].reshape(D, KV * Dh)
    qp, kp = predict_qk(xn, wq, wk, scfg.quant_method, scfg.quant_bits)
    if mode == "flat":  # (B, H, 1, L, *) layout matching attention_forward
        H = KV * G
        qh = qp.reshape(B, L, H, Dh).transpose(0, 2, 1, 3)[:, :, None]
        kh = kp.reshape(B, L, KV, Dh).transpose(0, 2, 1, 3)
        kh = jnp.repeat(kh, G, axis=1)
        qh = _cn(qh, ("batch", "heads", None, "seq", None))
        kh = _cn(kh, ("batch", "heads", "seq", None))
    else:
        qh = qp.reshape(B, L, KV, G, Dh).transpose(0, 2, 3, 1, 4)
        kh = kp.reshape(B, L, KV, Dh).transpose(0, 2, 1, 3)
        qh = _cn(qh, ("batch", "kv_heads", "qgroups", "seq", None))
    pam = jnp.einsum("bkgqd,bkld->bkgql", qh, kh) * (Dh ** -0.5)
    if scfg.causal:
        neg = jnp.asarray(jnp.finfo(pam.dtype).min / 2, pam.dtype)
        tri = jnp.tril(jnp.ones((L, L), dtype=bool))
        pam = jnp.where(tri, pam, neg)

    spa, mask = _topk.sparsify_pam(pam, scfg.k_ratio)
    if scfg.causal:
        tri = jnp.tril(jnp.ones((L, L), bool))
        mask = mask & tri
        spa = jnp.where(mask, spa, jnp.zeros_like(spa))
    sim = _sim.local_similarity(spa, scfg.window, scfg.s_threshold)
    kv_keep = _topk.kv_keep_from_mask(mask)
    if scfg.ffn_sparsity:
        # MFI votes across all H = KV*G heads
        leaders_h = sim.leader.reshape(B, KV * G, L)
        ffn = _mfi.mfi_ffn_sparsity(leaders_h, scfg.window, scfg.f_threshold)
        ffn_crit, ffn_leader = ffn.is_critical, ffn.leader
    else:
        ar = jnp.arange(L, dtype=jnp.int32)
        ffn_crit = jnp.ones((B, L), bool)
        ffn_leader = jnp.broadcast_to(ar, (B, L))
    return SparsityPlan(attn_mask=mask & kv_keep[..., None, :],
                        q_critical=sim.is_critical, q_leader=sim.leader,
                        kv_keep=kv_keep, ffn_critical=ffn_crit,
                        ffn_leader=ffn_leader)


def build_block_plan_chunked(cfg: ArchConfig, p: dict, xn: jax.Array):
    """Progressive-generation plan for long sequences (O(row_block * L)).

    Mirrors :func:`build_block_plan` but scans PAM row blocks -- the XLA
    mapping of the paper's progressive generation scheme (Sec. IV-C).
    """
    from repro.core.predict import predict_qk
    from repro.core.spls_chunked import chunked_plan_scan
    from repro.sharding.logical import constrain as _cn
    from .attention import head_shard_mode

    D, KV, Dh = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    B, L, _ = xn.shape
    scfg = cfg.spls
    mode = head_shard_mode(cfg)
    wq = p["attn"]["wq"].reshape(D, KV * G * Dh)
    wk = p["attn"]["wk"].reshape(D, KV * Dh)
    qp, kp = predict_qk(xn, wq, wk, scfg.quant_method, scfg.quant_bits)
    if mode == "flat":
        H = KV * G
        qh = qp.reshape(B, L, H, Dh).transpose(0, 2, 1, 3)[:, :, None]
        kh = jnp.repeat(kp.reshape(B, L, KV, Dh).transpose(0, 2, 1, 3),
                        G, axis=1)
        qh = _cn(qh, ("batch", "heads", None, "seq", None))
        kh = _cn(kh, ("batch", "heads", "seq", None))
    else:
        qh = qp.reshape(B, L, KV, G, Dh).transpose(0, 2, 3, 1, 4)
        kh = kp.reshape(B, L, KV, Dh).transpose(0, 2, 1, 3)
        qh = _cn(qh, ("batch", "kv_heads", "qgroups", "seq", None))
    head_names = (("heads", None) if mode == "flat"
                  else ("kv_heads", "qgroups"))
    return chunked_plan_scan(
        qh, kh, k_ratio=scfg.k_ratio, s_threshold=scfg.s_threshold,
        window=scfg.window, f_threshold=scfg.f_threshold,
        row_block=max(scfg.window, min(512, L)), causal=scfg.causal,
        head_names=head_names)


def _progressive_row_block(L: int, w: int) -> int:
    """Row-block size for the progressive planner: a window multiple, at
    most ~512 rows (the PAM block is O(row_block * L) per head)."""
    return max(w, (min(512, L) // w) * w)


def progressive_plan_blocks(cfg: ArchConfig, p: dict, xn: jax.Array,
                            row_block: Optional[int] = None,
                            votes_only: bool = False):
    """Iterate the progressive planner's row blocks for a full sequence.

    The single place that owns the predicted-head layout (mirroring
    :func:`head_shard_mode`), the window-aligned row blocking, and the
    tail padding -- both the full plan assembly
    (:func:`build_block_plan_progressive`) and the serving vote path
    (``repro.serving.pager.spls_token_votes``) consume it, so the two can
    never diverge.  Yields :class:`~repro.core.spls_chunked.ChunkPlanBlock`
    per block, or just the ``kv_any`` column-keep bools with
    ``votes_only=True`` (skipping the similarity stage, whose pairwise
    tensor is the largest intermediate of a full block).
    """
    from repro.core.predict import predict_qk
    from repro.core.spls_chunked import plan_chunk, plan_chunk_votes
    from repro.core.topk import topk_count
    from .attention import head_shard_mode

    D, KV, Dh = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    B, L, _ = xn.shape
    scfg = cfg.spls
    mode = head_shard_mode(cfg)
    wq = p["attn"]["wq"].reshape(D, KV * G * Dh)
    wk = p["attn"]["wk"].reshape(D, KV * Dh)
    qp, kp = predict_qk(xn, wq, wk, scfg.quant_method, scfg.quant_bits,
                        act_axis=-1)
    if mode == "flat":
        H = KV * G
        qh = qp.reshape(B, L, H, Dh).transpose(0, 2, 1, 3)[:, :, None]
        kh = jnp.repeat(kp.reshape(B, L, KV, Dh).transpose(0, 2, 1, 3),
                        G, axis=1)
    else:
        qh = qp.reshape(B, L, KV, G, Dh).transpose(0, 2, 3, 1, 4)
        kh = kp.reshape(B, L, KV, Dh).transpose(0, 2, 1, 3)

    w = scfg.window
    rb = row_block or _progressive_row_block(L, w)
    assert rb % w == 0, (rb, w)
    nblk = -(-L // rb)
    pad = nblk * rb - L
    if pad:
        qh = jnp.pad(qh, ((0, 0),) * 3 + ((0, pad), (0, 0)))
    k = topk_count(L, scfg.k_ratio)
    for i in range(nblk):
        common = dict(k=k, row0=i * rb, n_valid_rows=min(rb, L - i * rb),
                      n_cols=L, causal=cfg.causal)
        q_blk = qh[..., i * rb:(i + 1) * rb, :]
        if votes_only:
            yield plan_chunk_votes(q_blk, kh, **common)
        else:
            yield plan_chunk(q_blk, kh, s_threshold=scfg.s_threshold,
                             window=w, f_threshold=scfg.f_threshold,
                             **common)


def build_block_plan_progressive(cfg: ArchConfig, p: dict, xn: jax.Array,
                                 row_block: Optional[int] = None
                                 ) -> Optional[SparsityPlan]:
    """Serving-mode SPLS plan: the numerics a *streaming* predictor can
    reproduce exactly, assembled over the full sequence.

    Differs from :func:`build_block_plan` in exactly the two ways required
    for chunk-by-chunk reproducibility (the serving engines run this for
    full prefills and :func:`repro.core.spls_chunked.plan_chunk` per chunk;
    both must agree bit-for-bit):

      * **per-token quantization** (``act_axis=-1`` in ``predict_qk``):
        per-tensor scales depend on rows that have not arrived yet in a
        streaming prefill;
      * **bisection top-k** over scanned row blocks (never the full PAM --
        O(row_block * L) peak) with a threshold that is row-local, so any
        window-aligned blocking yields the same plan.

    Returns ``None`` when SPLS is disabled.
    """
    if not cfg.spls.enabled:
        return None
    B, L, _ = xn.shape
    scfg = cfg.spls
    blocks = list(progressive_plan_blocks(cfg, p, xn, row_block))

    cat = lambda xs, ax: xs[0] if len(xs) == 1 else jnp.concatenate(xs, ax)
    mask = cat([b.mask for b in blocks], -2)[..., :L, :]
    q_crit = cat([b.q_critical for b in blocks], -1)[..., :L]
    q_lead = cat([b.q_leader for b in blocks], -1)[..., :L]
    kv_keep = blocks[0].kv_any
    for b in blocks[1:]:
        kv_keep = kv_keep | b.kv_any
    if scfg.ffn_sparsity:
        ffn_crit = cat([b.ffn_critical for b in blocks], -1)[..., :L]
        ffn_lead = cat([b.ffn_leader for b in blocks], -1)[..., :L]
    else:
        ar = jnp.arange(L, dtype=jnp.int32)
        ffn_crit = jnp.ones((B, L), bool)
        ffn_lead = jnp.broadcast_to(ar, (B, L))
    # attn_mask == mask & kv_keep[..., None, :] identically: any column a
    # row's mask selects is by definition kept in that head, so the
    # intersection is a no-op (this is also what makes simulation-mode
    # execution reproducible row-locally by a streaming prefill).
    return SparsityPlan(attn_mask=mask, q_critical=q_crit, q_leader=q_lead,
                        kv_keep=kv_keep, ffn_critical=ffn_crit,
                        ffn_leader=ffn_lead)


_SPLS_CHUNK_THRESHOLD = 8192


def _capacities(cfg: ArchConfig, L: int) -> Tuple[Optional[int], Optional[int]]:
    s = cfg.spls
    qc = None if s.q_capacity_ratio >= 1.0 else max(
        s.window, math.ceil(s.q_capacity_ratio * L))
    kc = None if s.kv_capacity_ratio >= 1.0 else max(
        s.window, math.ceil(s.kv_capacity_ratio * L))
    return qc, kc


def block_forward(cfg: ArchConfig, blk: BlockCfg, p: dict, x: jax.Array,
                  cache_len: Optional[int] = None,
                  attn_backend: Optional[str] = None,
                  plan_mode: str = "auto"):
    """Full-sequence block.  x: (B, L, D).

    With ``cache_len`` (prefill) also returns the block's decode cache.
    ``attn_backend`` overrides ``cfg.attn_backend`` for the mixer (see
    :mod:`repro.models.attn_backend`).  ``plan_mode="progressive"`` builds
    the SPLS plan with :func:`build_block_plan_progressive` (streaming-
    reproducible numerics -- the serving engines use this so chunked and
    full prefills agree bit-for-bit); ``"auto"`` keeps the exact-top-k
    builder, switching to the ChunkedPlan path at long L.
    """
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    plan, cache = None, None
    if blk.mixer == "attn":
        from .attention import head_shard_mode
        # padded head mode (no divisible factorization) runs dense: the
        # SPLS plan layout would need garbage-head vote filtering -- noted
        # in DESIGN.md §Arch-applicability.
        if head_shard_mode(cfg) != "padded":
            if plan_mode == "progressive":
                plan = build_block_plan_progressive(cfg, p, xn)
            elif cfg.spls.enabled and x.shape[1] >= _SPLS_CHUNK_THRESHOLD:
                plan = build_block_plan_chunked(cfg, p, xn)
            else:
                plan = build_block_plan(cfg, p, xn)
        qc, kc = _capacities(cfg, x.shape[1]) if plan is not None else (None, None)
        h = attention_forward(cfg, p["attn"], xn, window=blk.window,
                              plan=plan, q_capacity=qc, kv_capacity=kc,
                              cache_len=cache_len, backend=attn_backend)
        if cache_len is not None:
            h, cache = h
    else:
        h = mamba_forward(cfg, p["mamba"], xn, want_cache=cache_len is not None)
        if cache_len is not None:
            h, cache = h
    if cfg.use_post_norm:
        h = rms_norm(h, p["post_ln1"], cfg.norm_eps)
    x = x + h

    if blk.has_ffn:
        xn2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        fn = lambda t: ffn_forward(cfg, blk.use_moe, p["ffn"], t)
        if plan is not None and cfg.spls.ffn_sparsity:
            qc, _ = _capacities(cfg, x.shape[1])
            if qc is not None:
                # capacity mode: the compute-backend axis decides how the
                # packed rows execute (repro.sparse_compute; "dense"
                # config default keeps the XLA pack/unpack closure); MoE
                # blocks keep it -- their capacity routing *is* the pack
                from repro.sparse_compute import (is_packed,
                                                  resolve_compute_backend)
                cb = resolve_compute_backend(cfg.compute_backend,
                                             sparse=True)
                if is_packed(cb) and not blk.use_moe:
                    from repro.core.sparse_exec import compact_rows
                    from repro.sparse_compute import packed_mlp
                    comp = compact_rows(plan.ffn_critical, qc,
                                        leader=plan.ffn_leader,
                                        window=cfg.spls.window)
                    h2 = packed_mlp(cfg, p["ffn"], xn2, comp, cb)
                else:
                    h2 = spls_ffn_packed(xn2, fn, plan, qc,
                                         window=cfg.spls.window)
            else:
                h2 = spls_ffn(xn2, fn, plan)
        else:
            h2 = fn(xn2)
        if cfg.use_post_norm:
            h2 = rms_norm(h2, p["post_ln2"], cfg.norm_eps)
        x = x + h2
    if cache_len is not None:
        return x, cache
    return x


def block_decode(cfg: ArchConfig, blk: BlockCfg, p: dict, x: jax.Array,
                 cache, pos: jax.Array, attn_backend: Optional[str] = None):
    """One-token decode.  x: (B, 1, D); returns (x, new_cache)."""
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    if blk.mixer == "attn":
        h, cache = attention_decode(cfg, p["attn"], xn, cache, pos,
                                    window=blk.window, backend=attn_backend)
    else:
        h, cache = mamba_decode(cfg, p["mamba"], xn, cache)
    if cfg.use_post_norm:
        h = rms_norm(h, p["post_ln1"], cfg.norm_eps)
    x = x + h
    if blk.has_ffn:
        xn2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        h2 = ffn_forward(cfg, blk.use_moe, p["ffn"], xn2)
        if cfg.use_post_norm:
            h2 = rms_norm(h2, p["post_ln2"], cfg.norm_eps)
        x = x + h2
    return x, cache
