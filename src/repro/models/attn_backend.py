"""Attention backend registry + dispatch.

Every way this repo can execute the attention mixer lives here, behind one
string-keyed registry, so the model, the serving engine, and the launch
steps all select the execution strategy the same way.  See
``src/repro/models/README.md`` for the selection rules and the semantics of
each backend.

Forward backends (full-sequence) all share one signature::

    fn(cfg, q, k, v, *, window, plan, q_capacity, kv_capacity) -> o

with ``q: (B, KV', G', L, Dh)``, ``k/v: (B, KV', L, Dh)`` in the head
layout produced by ``attention._project_qkv`` and ``o`` shaped like ``q``.

  * ``xla_dense``   -- materialized-scores softmax; with a plan, the
    simulation-mode SPLS semantics (:func:`spls_attention`): leader-row
    recovery + the full intra-row SPA mask.  The accuracy oracle.
  * ``xla_packed``  -- capacity-mode SPLS (:func:`spls_attention_packed`):
    critical rows / surviving columns packed to static capacities; real
    compute reduction with XLA static shapes.
  * ``xla_chunked`` -- KV-chunked online-softmax scan (flash recurrence in
    XLA); O(L * chunk) memory.  With a plan it runs
    :func:`spls_attention_chunked` (packed + chunked, index-based masks).
  * ``pallas_flash`` -- the Pallas kernel (``repro.kernels.flash_attention``)
    with the SPLS plan lowered to hardware-realizable block sparsity:
    ``kv_keep`` feeds the kernel's block-skip keep mask (dead K/V blocks are
    never computed -- the accelerator's zero-column pruning as structured
    block skips) and critical Q rows are packed to a block-rounded capacity
    via :func:`pack_by_mask`, carried through the kernel with their original
    positions (``q_pos``) and scattered back through the leader map.  The
    intra-row SPA top-k mask is intentionally *not* applied -- per-element
    masking is exactly the part a tiled MXU cannot skip; column + row
    sparsity is what the hardware realizes (cf. ``xla_chunked`` which shares
    these semantics and is the parity oracle under a plan).
    Runs compiled on TPU, ``interpret=True`` elsewhere (bit-accurate, slow).

Decode backends share::

    fn(cfg, q, k, v, *, pos, window) -> o

with ``q: (B, KV, G, Dh)`` (one token), ``k/v: (B, KV, S, Dh)`` caches.

  * ``xla_dense_decode``    -- dense scores over the whole cache (XLA).
  * ``pallas_flash_decode`` -- ``repro.kernels.flash_decode`` streaming the
    cache through VMEM in chunks (position- and window-aware block skip).

Paged decode backends (the serving engine's block-pool KV cache,
``repro.serving``) share::

    fn(cfg, q, k_pages, v_pages, *, pos_pages, tables, kv_len, pos,
       window) -> o

with ``q: (B, KV, G, Dh)``, ``k/v_pages: (KV, N, ps, Dh)`` page pools,
``pos_pages: (N, ps)`` original-position ids, ``tables: (B, P)`` block
tables, ``kv_len: (B,)`` written slots, ``pos: (B,)`` current original
position.

  * ``xla_paged_decode``    -- XLA gather of the block table into a
    contiguous view, then dense masked scores.  The fallback / oracle.
  * ``pallas_paged_decode`` -- ``repro.kernels.paged_decode``: the block
    table rides in as a scalar-prefetch operand and each page is DMA'd by
    the BlockSpec index map (no contiguous gather is ever materialized).

``"auto"`` resolves per call site from platform, sequence length, and the
sparsity mode -- see :func:`resolve_backend`.
"""

from __future__ import annotations

import math
import warnings
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sparse_exec import (gather_rows, pack_by_mask,
                                    spls_attention, spls_attention_chunked,
                                    spls_attention_packed, unpack_by_leader)
from repro.core.spls import SparsityPlan
from repro.core.spls_chunked import ChunkedPlan
from .common import softcap as _softcap

__all__ = ["register_backend", "get_backend", "available_backends",
           "resolve_backend", "AUTO", "CHUNK_THRESHOLD", "KV_CHUNK"]

AUTO = "auto"
# Raise (instead of warn) when an explicitly configured backend has the
# wrong kind for a call site; see resolve_backend.  The per-call `strict`
# argument overrides this global default.
STRICT_BACKEND_KIND = False
_warned_kind_mismatch: set = set()
# KV-chunked attention kicks in above this length (keeps scores << O(L^2))
CHUNK_THRESHOLD = 8192
KV_CHUNK = 2048
# Pallas tile sizes (also the granularity of SPLS q packing / kv skipping)
PALLAS_BLOCK_Q = 128
PALLAS_BLOCK_K = 128


class _Backend(NamedTuple):
    fn: Callable
    decode: bool
    doc: str
    paged: bool = False


_REGISTRY: Dict[str, _Backend] = {}


def register_backend(name: str, decode: bool = False, paged: bool = False,
                     doc: str = "") -> Callable:
    """Decorator registering ``fn`` under ``name``; ``decode`` marks
    single-token backends, ``paged`` marks block-pool paged-cache backends
    (different signatures, see module docstring)."""

    def deco(fn: Callable) -> Callable:
        _REGISTRY[name] = _Backend(fn, decode, doc or (fn.__doc__ or ""),
                                   paged)
        return fn

    return deco


def available_backends(decode: Optional[bool] = None,
                       paged: Optional[bool] = None) -> Tuple[str, ...]:
    """Registered backend names, optionally filtered by decode/paged-ness."""
    return tuple(sorted(n for n, b in _REGISTRY.items()
                        if (decode is None or b.decode == decode)
                        and (paged is None or b.paged == paged)))


def get_backend(name: str) -> Callable:
    try:
        return _REGISTRY[name].fn
    except KeyError:
        raise ValueError(
            f"unknown attention backend {name!r}; "
            f"registered: {available_backends()}") from None


def _platform() -> str:
    return jax.default_backend()


def _site_kind(decode: bool, paged: bool) -> str:
    return ("paged decode" if paged else "decode") if decode else "forward"


def resolve_backend(name: Optional[str], cfg, *, L: int, plan=None,
                    q_capacity: Optional[int] = None, decode: bool = False,
                    paged: bool = False,
                    platform: Optional[str] = None,
                    strict: Optional[bool] = None) -> str:
    """Map a configured backend name (possibly ``"auto"``/None) to a
    concrete registry key.

    An explicitly configured name whose kind does not match the call site
    (a forward name at a decode site, a dense decode name at a paged site,
    ...) falls back to that site's auto choice with a ``RuntimeWarning``
    (once per (name, site) pair), or raises when ``strict=True`` (per call)
    or :data:`STRICT_BACKEND_KIND` is set globally.

    The ``"auto"`` heuristic (documented in models/README.md):

    paged decode: TPU -> ``pallas_paged_decode``; else ``xla_paged_decode``.
    decode:   TPU -> ``pallas_flash_decode``; otherwise the inline dense
              decode path (``xla_dense``).
    forward:  1. ChunkedPlan (long-sequence progressive SPLS)
                 -> ``xla_chunked``  (the only consumer of index-based
                 packed chunking at O(Cq * chunk) memory);
              2. TPU -> ``pallas_flash`` (compiled kernel; with a plan the
                 hardware block-sparse lowering);
              3. plan + reduced q capacity -> ``xla_packed``;
              4. plan -> ``xla_dense`` (simulation-mode numerics);
              5. L > CHUNK_THRESHOLD -> ``xla_chunked``;
              6. otherwise -> ``xla_dense``.
    """
    name = name or AUTO
    if name != AUTO:
        b = _REGISTRY.get(name)
        if b is None:
            raise ValueError(
                f"unknown attention backend {name!r}; "
                f"registered: {available_backends()}")
        if b.decode == decode and b.paged == paged:
            return name
        # kind mismatch: the one config field drives every context, so a
        # name of the wrong kind for this site (forward at decode, dense
        # decode at a paged site, ...) falls through to the auto choice
        # for this site -- loudly, so a typo'd override cannot silently
        # serve through a different backend than the one asked for
        site = _site_kind(decode, paged)
        msg = (f"configured attention backend {name!r} is a "
               f"{_site_kind(b.decode, b.paged)} backend but this is a "
               f"{site} site; falling back to the auto choice for this "
               f"site (pass strict=True or set "
               f"repro.models.attn_backend.STRICT_BACKEND_KIND to raise)")
        if strict if strict is not None else STRICT_BACKEND_KIND:
            raise ValueError(msg)
        if (name, site) not in _warned_kind_mismatch:
            _warned_kind_mismatch.add((name, site))
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
    platform = platform or _platform()
    if decode and paged:
        return ("pallas_paged_decode" if platform == "tpu"
                else "xla_paged_decode")
    if decode:
        return ("pallas_flash_decode" if platform == "tpu"
                else "xla_dense_decode")
    if isinstance(plan, ChunkedPlan):
        return "xla_chunked"
    if platform == "tpu":
        return "pallas_flash"
    if plan is not None:
        if q_capacity is not None and q_capacity < L:
            return "xla_packed"
        return "xla_dense"
    if L > CHUNK_THRESHOLD:
        return "xla_chunked"
    return "xla_dense"


# ---------------------------------------------------------------------------
# forward backends
# ---------------------------------------------------------------------------

def _band_mask(L: int, window: Optional[int], causal: bool) -> jax.Array:
    i = jnp.arange(L)[:, None]
    j = jnp.arange(L)[None, :]
    m = (j <= i) if causal else jnp.ones((L, L), bool)
    if window is not None:
        m = m & (i - j < window) & (j - i < (1 if causal else window))
    return m


def _broadcast_kv(q: jax.Array, k: jax.Array, v: jax.Array):
    B, KVp, Gp, L, Dh = q.shape
    kr = jnp.broadcast_to(k[:, :, None], (B, KVp, Gp, L, Dh))
    vr = jnp.broadcast_to(v[:, :, None], (B, KVp, Gp, L, Dh))
    return kr, vr


def _window_plan(plan: SparsityPlan, L: int, window: Optional[int],
                 causal: bool) -> SparsityPlan:
    """Intersect a block's sliding window into the plan's attention mask so
    SPLS + SWA keeps the same semantics on every backend (the Pallas and
    chunked paths window through position indices instead)."""
    if window is None:
        return plan
    return plan._replace(attn_mask=plan.attn_mask
                         & _band_mask(L, window, causal))


@register_backend("xla_dense",
                  doc="materialized scores; simulation-mode SPLS with plan")
def xla_dense(cfg, q, k, v, *, window=None, plan=None, q_capacity=None,
              kv_capacity=None) -> jax.Array:
    L, Dh = q.shape[-2], q.shape[-1]
    if plan is not None:
        kr, vr = _broadcast_kv(q, k, v)
        plan = _window_plan(plan, L, window, cfg.causal)
        return spls_attention(q, kr, vr, plan, Dh ** -0.5, cfg.attn_softcap)
    s = jnp.einsum("bkgqd,bkld->bkgql", q, k) * (Dh ** -0.5)
    s = _softcap(s, cfg.attn_softcap)
    m = _band_mask(L, window, cfg.causal)
    s = jnp.where(m, s, jnp.asarray(-1e30, s.dtype))
    a = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bkgql,bkld->bkgqd", a, v)


@register_backend("xla_packed",
                  doc="capacity-mode SPLS: pack critical rows/columns")
def xla_packed(cfg, q, k, v, *, window=None, plan=None, q_capacity=None,
               kv_capacity=None) -> jax.Array:
    if plan is None:  # nothing to pack -- degenerate to the dense scores
        return xla_dense(cfg, q, k, v, window=window)
    L, Dh = q.shape[-2], q.shape[-1]
    kr, vr = _broadcast_kv(q, k, v)
    plan = _window_plan(plan, L, window, cfg.causal)
    return spls_attention_packed(q, kr, vr, plan, q_capacity or L,
                                 kv_capacity or L, Dh ** -0.5,
                                 cfg.attn_softcap)


@register_backend("xla_chunked",
                  doc="KV-chunked online-softmax scan (flash in XLA)")
def xla_chunked(cfg, q, k, v, *, window=None, plan=None, q_capacity=None,
                kv_capacity=None) -> jax.Array:
    B, KVp, Gp, L, Dh = q.shape
    if plan is not None:
        # spls_attention_chunked pads ragged capacities to whole KV chunks
        # internally, so chunking (and O(Cq * chunk) memory) always holds
        return spls_attention_chunked(q, k, v, plan, q_capacity or L,
                                      min(kv_capacity or L, L),
                                      Dh ** -0.5, cfg.attn_softcap,
                                      kv_chunk=KV_CHUNK, causal=cfg.causal,
                                      window=window)

    C = min(KV_CHUNK, L)
    pad = (-L) % C
    if pad:  # ragged tail: padded columns are masked out by `kj < L`
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nC = (L + pad) // C
    scale = Dh ** -0.5
    qi = jnp.arange(L)

    def body(carry, ck):
        m_run, l_run, acc = carry
        k_c, v_c, c0 = ck
        s = jnp.einsum("bkgqd,bkld->bkgql", q, k_c).astype(jnp.float32) * scale
        s = _softcap(s, cfg.attn_softcap)
        kj = c0 + jnp.arange(C)
        mask = jnp.broadcast_to(kj[None, :] < L, (L, C))
        if cfg.causal:
            mask = mask & (kj[None, :] <= qi[:, None])
        if window is not None:
            mask = mask & (qi[:, None] - kj[None, :] < window)
            if not cfg.causal:
                mask = mask & (kj[None, :] - qi[:, None] < window)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m_run, s.max(-1))
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None]) * mask.astype(jnp.float32)
        l_new = l_run * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgql,bkld->bkgqd", p.astype(v_c.dtype), v_c).astype(jnp.float32)
        return (m_new, l_new, acc), None

    kc = k.reshape(B, KVp, nC, C, Dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, KVp, nC, C, Dh).transpose(2, 0, 1, 3, 4)
    offs = jnp.arange(nC) * C
    init = (jnp.full((B, KVp, Gp, L), -1e30, jnp.float32),
            jnp.zeros((B, KVp, Gp, L), jnp.float32),
            jnp.zeros((B, KVp, Gp, L, Dh), jnp.float32))
    (m_f, l_f, acc), _ = jax.lax.scan(body, init, (kc, vc, offs))
    out = acc / jnp.maximum(l_f, 1e-9)[..., None]
    return out.astype(q.dtype)


@register_backend("pallas_flash",
                  doc="Pallas kernel; SPLS as block-skip + packed rows")
def pallas_flash(cfg, q, k, v, *, window=None, plan=None, q_capacity=None,
                 kv_capacity=None) -> jax.Array:
    from repro.kernels.flash_attention import flash_attention

    B, KVp, Gp, L, Dh = q.shape
    H = KVp * Gp
    interpret = _platform() != "tpu"
    qf = q.reshape(B, H, L, Dh)
    # k/v stay in the grouped (B, KV', L, Dh) layout: the kernel reads the
    # shared group K/V through its BlockSpec index map (no H-wide copy)
    kf, vf = k, v

    if plan is None:
        o = flash_attention(qf, kf, vf, causal=cfg.causal, window=window,
                            softcap=cfg.attn_softcap,
                            block_q=PALLAS_BLOCK_Q, block_k=PALLAS_BLOCK_K,
                            interpret=interpret)
        return o.reshape(B, KVp, Gp, L, Dh)

    # SPLS plan -> hardware block sparsity:
    #  * kv_keep feeds the kernel keep mask (dead K blocks skipped whole);
    #  * critical Q rows packed to a block-rounded capacity, carried with
    #    their original positions, leader-recovered after the call.
    crit = plan.q_critical.reshape(B, H, L)
    keep = plan.kv_keep.reshape(B, H, L)
    leader = plan.q_leader.reshape(B, H, L)
    bq = min(PALLAS_BLOCK_Q, L)
    Cq = min(q_capacity or L, L)
    Cq = min(L, -(-Cq // bq) * bq)      # round capacity up to whole q blocks
    q_perm, q_slot = pack_by_mask(crit, Cq)
    qp = gather_rows(qf, q_perm)
    op = flash_attention(qp, kf, vf, causal=cfg.causal, window=window,
                         softcap=cfg.attn_softcap, kv_keep=keep,
                         q_pos=q_perm,
                         block_q=PALLAS_BLOCK_Q, block_k=PALLAS_BLOCK_K,
                         interpret=interpret)
    o = unpack_by_leader(op, q_slot, leader)
    return o.reshape(B, KVp, Gp, L, Dh)


# ---------------------------------------------------------------------------
# decode backends
# ---------------------------------------------------------------------------

@register_backend("xla_dense_decode", decode=True,
                  doc="dense one-token decode over the whole cache")
def xla_dense_decode(cfg, q, k, v, *, pos, window=None) -> jax.Array:
    """q: (B, KV, G, Dh) one token; k/v: (B, KV, S, Dh); pos: (B,)."""
    S, Dh = k.shape[2], q.shape[-1]
    s = jnp.einsum("bkgd,bkld->bkgl", q, k) * (Dh ** -0.5)
    s = _softcap(s, cfg.attn_softcap)
    j = jnp.arange(S)[None, :]
    m = j <= pos[:, None]
    if window is not None:
        m = m & (pos[:, None] - j < window)
    s = jnp.where(m[:, None, None, :], s, jnp.asarray(-1e30, s.dtype))
    a = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bkgl,bkld->bkgd", a, v)


@register_backend("pallas_flash_decode", decode=True,
                  doc="Pallas decode kernel streaming the KV cache")
def pallas_flash_decode(cfg, q, k, v, *, pos, window=None) -> jax.Array:
    """q: (B, KV, G, Dh) one token; k/v: (B, KV, S, Dh); pos: (B,)."""
    from repro.kernels.flash_decode import flash_decode

    S = k.shape[2]
    bk = min(512, S)
    pad = (-S) % bk
    if pad:  # padded cache slots sit beyond `pos` -> masked by the kernel
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return flash_decode(q, k, v, pos, softcap=cfg.attn_softcap,
                        window=window, block_k=bk,
                        interpret=_platform() != "tpu")


# ---------------------------------------------------------------------------
# paged decode backends (block-pool KV cache, repro.serving)
# ---------------------------------------------------------------------------

@register_backend("xla_paged_decode", decode=True, paged=True,
                  doc="XLA block-table gather + dense masked decode")
def xla_paged_decode(cfg, q, k_pages, v_pages, *, pos_pages, tables, kv_len,
                     pos, window=None) -> jax.Array:
    """q: (B, KV, G, Dh); k/v_pages: (KV, N, ps, Dh); pos_pages: (N, ps);
    tables: (B, P); kv_len/pos: (B,).  Gathers the sequence's pages into a
    contiguous (B, KV, P*ps, Dh) view, then runs the dense decode math with
    a written-slot mask (slot < kv_len) and an original-position window."""
    B, KV, G, Dh = q.shape
    ps = k_pages.shape[2]
    P = tables.shape[1]
    S = P * ps
    kg = jnp.moveaxis(k_pages[:, tables], 1, 0).reshape(B, KV, S, Dh)
    vg = jnp.moveaxis(v_pages[:, tables], 1, 0).reshape(B, KV, S, Dh)
    pg = pos_pages[tables].reshape(B, S)
    s = jnp.einsum("bkgd,bkld->bkgl", q, kg) * (Dh ** -0.5)
    s = _softcap(s, cfg.attn_softcap)
    slot = jnp.arange(S)[None, :]
    m = slot < kv_len[:, None]
    if window is not None:
        m = m & (pos[:, None] - pg < window)
    s = jnp.where(m[:, None, None, :], s, jnp.asarray(-1e30, s.dtype))
    a = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bkgl,bkld->bkgd", a, vg)


@register_backend("pallas_paged_decode", decode=True, paged=True,
                  doc="Pallas paged decode; block-table gather in the DMA")
def pallas_paged_decode(cfg, q, k_pages, v_pages, *, pos_pages, tables,
                        kv_len, pos, window=None) -> jax.Array:
    """Same contract as :func:`xla_paged_decode`, executed by
    ``repro.kernels.paged_decode.paged_flash_decode``."""
    from repro.kernels.paged_decode import paged_flash_decode

    return paged_flash_decode(q, k_pages, v_pages, pos_pages, tables,
                              kv_len, pos, softcap=cfg.attn_softcap,
                              window=window,
                              interpret=_platform() != "tpu")
