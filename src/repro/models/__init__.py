"""Composable model definitions (pure JAX, functional parameters)."""

from .common import apply_rope, layer_norm, rms_norm, rope_freqs, softcap
from .attn_backend import (available_backends, get_backend, register_backend,
                           resolve_backend)
from .attention import (KVCache, attention_decode, attention_forward,
                        init_attention, init_kv_cache)
from .moe import (ffn_forward, init_ffn, init_mlp, init_moe, mlp_forward,
                  moe_forward)
from .mamba import (MambaCache, init_mamba, init_mamba_cache, mamba_decode,
                    mamba_forward, ssd_chunked)
from .blocks import block_decode, block_forward, init_block, init_block_cache
from .model import (abstract_params, decode_step, forward, init_cache,
                    init_params, loss_fn, prefill)
