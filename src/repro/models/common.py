"""Shared model building blocks: norms, RoPE, initializers, dtype helpers."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["dtype_of", "rms_norm", "layer_norm", "rope_freqs", "apply_rope",
           "dense_init", "softcap", "Activations"]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def rope_freqs(positions: jax.Array, head_dim: int,
               theta: float = 10000.0) -> Tuple[jax.Array, jax.Array]:
    """(sin, cos) of shape positions.shape + (head_dim // 2,)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate pairs.  x: (..., L, Dh); sin/cos: broadcastable (..., L, Dh/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype,
               fan_in: Optional[int] = None) -> jax.Array:
    """Truncated-normal with 1/sqrt(fan_in) scaling (LeCun-ish)."""
    fan_in = fan_in or shape[0]
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


class Activations:
    """Activation registry for the FFN (gated variants use 2 input mats)."""

    @staticmethod
    def gated(name: str) -> bool:
        return name in ("silu", "gelu")

    @staticmethod
    def fn(name: str):
        return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
                "gelu_mlp": jax.nn.gelu}[name]
