"""The generic causal LM / encoder: scan-over-periods composition.

The layer stack is ``cfg.period`` (a tuple of heterogeneous blocks) repeated
``cfg.n_periods`` times.  Period parameters are stacked on a leading axis and
the stack is traversed with ``lax.scan`` so the lowered HLO contains *one*
period body regardless of depth -- essential to keep the 40-cell multi-pod
dry-run compile times sane (llama3-405b has 126 layers).  ``cfg.remat``
wraps the period body in ``jax.checkpoint`` for training.

Modality frontends (audio/vlm archs) are STUBS per the assignment: with
``cfg.input_mode == "embeddings"`` the model consumes precomputed frame /
patch embeddings of shape (B, L, D) instead of token ids.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.logical import constrain
from .blocks import (block_decode, block_forward, init_block,
                     init_block_cache)
from .common import dense_init, dtype_of, rms_norm, softcap

__all__ = ["init_params", "abstract_params", "forward", "loss_fn",
           "init_cache", "decode_step", "prefill", "embed_inputs",
           "head_logits"]

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_period(cfg: ArchConfig, key: jax.Array, dtype) -> Tuple[dict, ...]:
    ks = jax.random.split(key, len(cfg.period))
    return tuple(init_block(cfg, blk, k, dtype)
                 for blk, k in zip(cfg.period, ks))


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    p: Params = {}
    p["embed"] = dense_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype,
                            fan_in=cfg.d_model)
    pkeys = jax.random.split(k_layers, cfg.n_periods)
    p["periods"] = jax.vmap(lambda k: _init_period(cfg, k, dtype))(pkeys)
    p["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tied_embeddings:
        p["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                  dtype, fan_in=cfg.d_model)
    return p


def abstract_params(cfg: ArchConfig) -> Params:
    """ShapeDtypeStruct pytree -- no allocation (dry-run path)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _embed_in(cfg: ArchConfig, params: Params, inputs: jax.Array) -> jax.Array:
    dtype = dtype_of(cfg.compute_dtype)
    if cfg.input_mode == "tokens":
        x = params["embed"][inputs].astype(dtype)
    else:  # modality stub: precomputed embeddings
        x = inputs.astype(dtype)
    if cfg.scale_embedding:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    return constrain(x, ("batch", "seq", "embed"))


def _head_out(cfg: ArchConfig, params: Params, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tied_embeddings else params["lm_head"])
    logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
    logits = softcap(logits, cfg.final_softcap)
    return constrain(logits, ("batch", "seq", "vocab"))


# public seams for alternative execution layers (e.g. the paged serving
# engine in repro.serving, which runs its own period scan over a paged cache)
def embed_inputs(cfg: ArchConfig, params: Params, inputs: jax.Array):
    """Token/embedding frontend: (B, L)[int] or (B, L, D) -> (B, L, D)."""
    return _embed_in(cfg, params, inputs)


def head_logits(cfg: ArchConfig, params: Params, x: jax.Array) -> jax.Array:
    """Final norm + LM head: (B, L, D) -> (B, L, V)."""
    return _head_out(cfg, params, x)


def _period_fn(cfg: ArchConfig, x: jax.Array, pparams) -> jax.Array:
    dtype = dtype_of(cfg.compute_dtype)
    pparams = jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, pparams)
    # layer-boundary activations (the remat save points) are seq-sharded
    # over the model axis (Megatron sequence parallelism)
    x = constrain(x, ("batch", "act_seq", "embed"))
    for blk, bp in zip(cfg.period, pparams):
        x = block_forward(cfg, blk, bp, x)
    return x


def forward(cfg: ArchConfig, params: Params, inputs: jax.Array) -> jax.Array:
    """inputs: (B, L) int tokens or (B, L, D) embeddings -> (B, L, V)."""
    x = _embed_in(cfg, params, inputs)
    body = functools.partial(_period_fn, cfg)
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    def scan_body(carry, pparams):
        return body(carry, pparams), None

    x, _ = jax.lax.scan(scan_body, x, params["periods"])
    return _head_out(cfg, params, x)


def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Cross-entropy LM loss.  batch: {inputs, labels[, mask]}."""
    logits = forward(cfg, params, batch["inputs"])
    labels = batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    acc = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    metrics = {"loss": loss, "accuracy": (acc * mask).sum() / denom,
               "tokens": mask.sum()}
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode over a scanned cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Stacked-over-periods cache pytree (ShapeDtypeStruct-compatible)."""
    dtype = dtype_of(cfg.compute_dtype)

    def one(_):
        return tuple(init_block_cache(cfg, blk, batch, max_len, dtype)
                     for blk in cfg.period)

    return jax.vmap(one)(jnp.arange(cfg.n_periods))


def decode_step(cfg: ArchConfig, params: Params, cache, tokens: jax.Array,
                pos: jax.Array):
    """One decode step.  tokens: (B, 1) or (B, 1, D); pos: (B,).

    Returns (logits (B, 1, V), new_cache).  The period scan threads the
    token activation as carry and the per-period cache as scanned xs/ys.
    """
    x = _embed_in(cfg, params, tokens)
    dtype = dtype_of(cfg.compute_dtype)

    def scan_body(x, inp):
        pparams, pcache = inp
        pparams = jax.tree.map(
            lambda a: a.astype(dtype)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, pparams)
        new_caches = []
        for blk, bp, bc in zip(cfg.period, pparams, pcache):
            x, nc = block_decode(cfg, blk, bp, x, bc, pos)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(scan_body, x, (params["periods"], cache))
    return _head_out(cfg, params, x), new_cache


def prefill(cfg: ArchConfig, params: Params, inputs: jax.Array,
            max_len: Optional[int] = None, plan_mode: str = "auto"):
    """Process a full prompt, returning (logits, cache) for decoding.

    When SPLS is enabled this is exactly the paper's scenario: the sparsity
    plan is predicted per block before QKV generation and the prompt is
    processed sparsely; the KV cache still holds every position (pruned
    columns would be an additional paper-faithful saving -- see DESIGN.md).
    ``plan_mode="progressive"`` selects the streaming-reproducible plan
    builder (see :func:`repro.models.blocks.block_forward`); the serving
    engines use it so chunked and whole-prompt prefills agree exactly.
    """
    L = inputs.shape[1]
    S = max_len or L
    dtype = dtype_of(cfg.compute_dtype)
    x = _embed_in(cfg, params, inputs)

    def scan_body(x, pparams):
        pparams = jax.tree.map(
            lambda a: a.astype(dtype)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, pparams)
        caches = []
        for blk, bp in zip(cfg.period, pparams):
            x, c = block_forward(cfg, blk, bp, x, cache_len=S,
                                 plan_mode=plan_mode)
            caches.append(c)
        return x, tuple(caches)

    x, cache = jax.lax.scan(scan_body, x, params["periods"])
    return _head_out(cfg, params, x), cache
