"""Mamba2 (SSD -- state-space duality) mixer, chunked scan + decode step.

Follows the minimal SSD formulation of Dao & Gu (arXiv:2405.21060): the
selective state-space recurrence is computed chunk-wise as (i) an intra-chunk
"attention-like" quadratic term and (ii) an inter-chunk recurrence over
per-chunk final states, carried with ``lax.scan``.  B/C are shared across
heads (ngroups = 1).  Decode keeps a constant-size recurrent state, which is
what makes the ``long_500k`` cell trivially sub-quadratic for SSM archs.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.logical import constrain
from .common import dense_init, rms_norm

__all__ = ["init_mamba", "mamba_forward", "mamba_decode", "MambaCache",
           "init_mamba_cache", "ssd_chunked"]


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, conv_channels, W)   rolling conv window
    ssd: jax.Array    # (B, H, P, N)            recurrent state


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> MambaCache:
    di, n = cfg.d_inner, cfg.ssm_state
    h, p = cfg.mamba_nheads, cfg.mamba_headdim
    return MambaCache(
        conv=jnp.zeros((batch, di + 2 * n, cfg.conv_width), dtype),
        ssd=jnp.zeros((batch, h, p, n), jnp.float32))


def init_mamba(cfg: ArchConfig, key: jax.Array, dtype) -> dict:
    D, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.mamba_nheads
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * n + h  # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], (D, proj_out), dtype, fan_in=D),
        "conv_w": dense_init(ks[1], (di + 2 * n, cfg.conv_width), dtype,
                             fan_in=cfg.conv_width),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gate_norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], (di, D), dtype, fan_in=di),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.mamba_nheads
    z, xc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    return z, xc, dt  # xc = [x | B | C] -> conv channels


def _segsum(x: jax.Array) -> jax.Array:
    """Lower-triangular segment sums: out[..., i, j] = sum_{j < t <= i} x[t]."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                init_state: jax.Array | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  x: (b,l,h,p); dt: (b,l,h); A: (h,); B,C: (b,l,n).

    Returns (y (b,l,h,p), final_state (b,h,p,n)).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert l % chunk == 0, f"seq {l} not divisible by chunk {chunk}"
    nc, cl = l // chunk, chunk

    xdt = x * dt[..., None]
    dA = (dt * A).reshape(b, nc, cl, h).transpose(0, 3, 1, 2)  # (b,h,nc,cl)
    dA_cs = jnp.cumsum(dA, axis=-1)

    xc = xdt.reshape(b, nc, cl, h, p)
    Bc = B.reshape(b, nc, cl, n)
    Cc = C.reshape(b, nc, cl, n)

    # (i) intra-chunk quadratic term
    Lmat = jnp.exp(_segsum(dA))                                # (b,h,nc,s,t)
    y_diag = jnp.einsum("bcsn,bctn,bhcst,bcthp->bcshp", Cc, Bc, Lmat, xc)

    # (ii) per-chunk final states + inter-chunk recurrence
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)           # (b,h,nc,cl)
    states = jnp.einsum("bctn,bhct,bcthp->bchpn", Bc, decay_states, xc)
    chunk_decay = jnp.exp(dA_cs[..., -1])                     # (b,h,nc)

    s0 = (jnp.zeros((b, h, p, n), x.dtype) if init_state is None
          else init_state.astype(x.dtype))

    def step(carry, inp):
        st, dec = inp                    # (b,h,p,n), (b,h)
        prev = carry
        new = prev * dec[..., None, None] + st
        return new, prev                 # emit state *before* this chunk

    xs = (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1))
    final, prev_states = jax.lax.scan(step, s0, xs)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (b,nc,h,p,n)

    state_decay_out = jnp.exp(dA_cs)                          # (b,h,nc,cl)
    y_off = jnp.einsum("bcsn,bchpn,bhcs->bcshp", Cc, prev_states,
                       state_decay_out)
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final


def _conv1d_causal(xc: jax.Array, w: jax.Array, bias: jax.Array,
                   state: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv.  xc: (B, L, Ch); w: (Ch, W)."""
    W = w.shape[-1]
    x = xc.swapaxes(-1, -2)  # (B, Ch, L)
    if state is None:
        x = jnp.pad(x, ((0, 0), (0, 0), (W - 1, 0)))
    else:
        x = jnp.concatenate([state[..., 1:], x], axis=-1)
    out = sum(x[..., i:i + xc.shape[1]] * w[:, i][None, :, None]
              for i in range(W))
    return jax.nn.silu(out + bias[None, :, None]).swapaxes(-1, -2)


def mamba_forward(cfg: ArchConfig, pr: dict, u: jax.Array,
                  chunk: int = 256, want_cache: bool = False):
    """Full-sequence Mamba2 mixer.  u: (B, L, D) -> (B, L, D).

    With ``want_cache`` also returns the MambaCache for decoding.
    """
    B_, L, D = u.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.mamba_nheads, cfg.mamba_headdim
    chunk = min(chunk, L)
    if L % chunk:
        chunk = 1  # fallback for ragged tiny sequences (smoke tests)
    zxbcdt = jnp.einsum("bld,de->ble", u, pr["in_proj"])
    z, xc, dt = _split_proj(cfg, zxbcdt)
    conv_tail = None
    if want_cache:
        W = cfg.conv_width
        tail = xc[:, -W:, :] if L >= W else jnp.pad(
            xc, ((0, 0), (W - L, 0), (0, 0)))
        conv_tail = jnp.swapaxes(tail, 1, 2)
    xc = _conv1d_causal(xc, pr["conv_w"], pr["conv_b"])
    x, Bm, Cm = jnp.split(xc, [di, di + n], axis=-1)
    x = constrain(x, ("batch", "seq", "inner"))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + pr["dt_bias"])
    A = -jnp.exp(pr["A_log"])
    xh = x.reshape(B_, L, h, p)
    y, final = ssd_chunked(xh.astype(jnp.float32), dt, A,
                           Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                           chunk)
    y = y + pr["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, L, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), pr["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, pr["out_proj"])
    out = constrain(out, ("batch", "seq", "embed"))
    if want_cache:
        return out, MambaCache(conv=conv_tail, ssd=final)
    return out


def mamba_decode(cfg: ArchConfig, pr: dict, u: jax.Array,
                 cache: MambaCache) -> Tuple[jax.Array, MambaCache]:
    """One-token recurrent step.  u: (B, 1, D)."""
    B_, _, D = u.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.mamba_nheads, cfg.mamba_headdim
    zxbcdt = jnp.einsum("bld,de->ble", u, pr["in_proj"])[:, 0]
    z, xc, dt = _split_proj(cfg, zxbcdt)

    conv = jnp.concatenate([cache.conv[..., 1:], xc[..., None]], axis=-1)
    xc = jax.nn.silu((conv * pr["conv_w"][None]).sum(-1) + pr["conv_b"])
    x, Bm, Cm = jnp.split(xc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + pr["dt_bias"])   # (B, h)
    A = -jnp.exp(pr["A_log"])
    dA = jnp.exp(dt * A)                                           # (B, h)
    xh = x.reshape(B_, h, p).astype(jnp.float32)
    dBx = (dt[..., None, None] * xh[..., None]
           * Bm.astype(jnp.float32)[:, None, None, :])             # (B,h,p,n)
    state = cache.ssd * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + pr["D"][None, :, None] * xh
    y = y.reshape(B_, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), pr["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, pr["out_proj"])[:, None]
    return out, MambaCache(conv=conv, ssd=state)
