"""GQA attention with RoPE, qk-norm, sliding window, logit soft-capping,
KV-cache decode, and SPLS sparse execution.

Head layout & tensor parallelism.  Weights keep an explicit (KV, G)
structure (``G = n_heads // n_kv_heads`` query heads per KV group); at trace
time :func:`head_shard_mode` picks how heads bind to the mesh's model axis:

  * **structured** -- KV (or G) divides the model axis: shard that axis
    directly; attention einsums stay local (llama3 kv=8 < 16 shards G=16,
    gemma2/olmoe shard KV=16).
  * **flat** -- neither divides but H = KV*G does (h2o/dbrx/jamba/pixtral:
    kv=8, G<16, H%16==0): flatten heads, repeat the (small, replicated) KV
    heads locally per device -- no communication, each device materializes
    only its H/|model| KV copies.
  * **replicated** -- nothing divides (musicgen H=24): attention replicates
    over the model axis; TP still comes from FFN + vocab.  Noted in
    DESIGN.md.

Execution strategy is delegated to the **attention backend registry**
(:mod:`repro.models.attn_backend`; selection rules documented in
``src/repro/models/README.md``): ``cfg.attn_backend`` (default ``"auto"``)
or an explicit ``backend=`` argument picks between the materialized-scores
path (``xla_dense``), capacity-packed SPLS (``xla_packed``), the KV-chunked
online-softmax scan (``xla_chunked``), and the Pallas flash kernels
(``pallas_flash`` / ``pallas_flash_decode`` -- compiled on TPU, interpret
mode elsewhere), with the SPLS :class:`SparsityPlan` lowered to block-level
K/V skipping + packed critical Q rows on the Pallas path.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.spls import SparsityPlan
from repro.sharding.logical import constrain
from .attn_backend import get_backend, resolve_backend
from .common import apply_rope, dense_init, rms_norm, rope_freqs

__all__ = ["init_attention", "attention_forward", "attention_decode",
           "KVCache", "init_kv_cache", "head_shard_mode", "project_qkv",
           "project_kv", "output_proj"]


class KVCache(NamedTuple):
    k: jax.Array          # (B, KV, S_max, Dh)
    v: jax.Array          # (B, KV, S_max, Dh)


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> KVCache:
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    z = jnp.zeros((batch, kv, max_len, dh), dtype)
    return KVCache(k=z, v=z)


def head_shard_mode(cfg: ArchConfig) -> str:
    """'structured' | 'flat' | 'replicated' -- see module docstring."""
    from repro.sharding.logical import _current_mesh
    mesh = _current_mesh()
    if mesh is None:
        return "structured"
    m = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    KV = cfg.n_kv_heads
    G = cfg.n_heads // max(KV, 1)
    if m <= 1 or KV % m == 0 or G % m == 0:
        return "structured"
    if cfg.n_heads % m == 0:
        return "flat"
    return "padded"


def _pad_heads_to(cfg: ArchConfig) -> int:
    """Padded head count for 'padded' mode: next multiple of |model|.

    Beyond-paper optimization (EXPERIMENTS.md §Perf, musicgen cell): when no
    head factorization divides the model axis (H=24 on 16), the projections
    are zero-padded to H'=32 *at trace time*.  Padded heads produce garbage
    attention outputs but their ``wo`` rows are zero, so the block output is
    bit-identical -- and attention compute/memory shards 16-way instead of
    replicating.
    """
    from repro.sharding.logical import _current_mesh
    mesh = _current_mesh()
    m = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    H = cfg.n_heads
    return -(-H // m) * m


def init_attention(cfg: ArchConfig, key: jax.Array, dtype) -> dict:
    D, KV, Dh = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, KV, G, Dh), dtype, fan_in=D),
        "wk": dense_init(ks[1], (D, KV, Dh), dtype, fan_in=D),
        "wv": dense_init(ks[2], (D, KV, Dh), dtype, fan_in=D),
        "wo": dense_init(ks[3], (KV, G, Dh, D), dtype, fan_in=KV * G * Dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((Dh,), dtype)
        p["k_norm"] = jnp.zeros((Dh,), dtype)
    return p


def _project_qkv(cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array,
                 mode: str = "structured"):
    """x (B, L, D) -> q (B, KV', G', L, Dh), k/v (B, KV', L, Dh).

    structured: KV' = KV, G' = G.   flat: KV' = H, G' = 1 (KV repeated).
    """
    B, L, D = x.shape
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    if mode in ("flat", "padded"):
        H = KV * G
        wq = p["wq"].reshape(D, H, Dh)
        wk, wv = p["wk"], p["wv"]
        if mode == "padded":
            Hp = _pad_heads_to(cfg)
            wq = jnp.pad(wq, ((0, 0), (0, Hp - H), (0, 0)))
            # pad KV to H' as well (each padded head attends independently)
            wk = jnp.pad(jnp.repeat(wk, G, axis=1),
                         ((0, 0), (0, Hp - H), (0, 0)))
            wv = jnp.pad(jnp.repeat(wv, G, axis=1),
                         ((0, 0), (0, Hp - H), (0, 0)))
            G = 1  # KV now per-head
        q = jnp.einsum("bld,dhe->bhle", x, wq)
        q = constrain(q, ("batch", "heads", "seq", None))
        k = jnp.einsum("bld,dkh->bklh", x, wk)
        v = jnp.einsum("bld,dkh->bklh", x, wv)
        if mode == "flat":
            k = jnp.repeat(k, G, axis=1)
            v = jnp.repeat(v, G, axis=1)
        k = constrain(k, ("batch", "heads", "seq", None))
        v = constrain(v, ("batch", "heads", "seq", None))
        q = q[:, :, None]  # (B, H', 1, L, Dh)
    else:
        q = jnp.einsum("bld,dkgh->bkglh", x, p["wq"])
        k = jnp.einsum("bld,dkh->bklh", x, p["wk"])
        v = jnp.einsum("bld,dkh->bklh", x, p["wv"])
        q = constrain(q, ("batch", "kv_heads", "qgroups", "seq", None))
        k = constrain(k, ("batch", "kv_heads", "seq", None))
        v = constrain(v, ("batch", "kv_heads", "seq", None))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    sin, cos = rope_freqs(positions, Dh, cfg.rope_theta)
    q = apply_rope(q, sin[:, None, None], cos[:, None, None])
    k = apply_rope(k, sin[:, None], cos[:, None])
    return q, k, v


def _out_proj(cfg: ArchConfig, p: dict, o: jax.Array, mode: str) -> jax.Array:
    """o (B, KV', G', L, Dh) -> (B, L, D)."""
    KV, Dh, D = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.d_model
    G = cfg.n_heads // KV
    if mode in ("flat", "padded"):
        wo = p["wo"].reshape(KV * G, Dh, D)
        if mode == "padded":
            Hp = _pad_heads_to(cfg)
            # zero wo rows for padded heads -> output bit-identical
            wo = jnp.pad(wo, ((0, Hp - KV * G), (0, 0), (0, 0)))
        out = jnp.einsum("bhld,hdm->blm", o[:, :, 0], wo)
    else:
        out = jnp.einsum("bkgld,kgdm->blm", o, p["wo"])
    return constrain(out, ("batch", "seq", "embed"))


def _project_kv(cfg: ArchConfig, p: dict, x: jax.Array,
                positions: jax.Array, mode: str = "structured",
                perm: Optional[jax.Array] = None,
                compute_backend: str = "dense"):
    """K/V-only projection seam: x (B, L, D) -> k/v (structured layout).

    Row-for-row identical to the k/v half of :func:`_project_qkv`.  The
    seam dispatches on the **compute backend**: with ``perm`` (a packed
    column subset from the horizon-finalized prune vote,
    :mod:`repro.core.planner`) the projection runs packed through
    :func:`repro.sparse_compute.packed.packed_project_kv` -- only the
    surviving ``C = len(perm)`` columns are computed (``(1, KV, C, Dh)``
    out, the ``gathered_matmul`` path) -- while ``perm=None`` keeps the
    dense ``(B, KV, L, Dh)`` projection of every chunk row (required
    until a vote finalizes; ``vote_horizon=None`` serving and all
    non-serving callers).
    """
    assert mode == "structured", "packed serving keeps the structured layout"
    if perm is not None:
        from repro.sparse_compute.packed import packed_project_kv
        assert x.shape[0] == 1, "packed K/V projection is per-sequence"
        return packed_project_kv(cfg, p, x, positions.reshape(-1), perm,
                                 compute_backend)
    Dh = cfg.resolved_head_dim
    k = jnp.einsum("bld,dkh->bklh", x, p["wk"])
    v = jnp.einsum("bld,dkh->bklh", x, p["wv"])
    k = constrain(k, ("batch", "kv_heads", "seq", None))
    v = constrain(v, ("batch", "kv_heads", "seq", None))
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    sin, cos = rope_freqs(positions, Dh, cfg.rope_theta)
    k = apply_rope(k, sin[:, None], cos[:, None])
    return k, v


# public seams for alternative execution layers (the paged serving engine
# projects QKV / re-projects outputs itself, around its block-pool cache)
project_qkv = _project_qkv
project_kv = _project_kv
output_proj = _out_proj


def attention_forward(cfg: ArchConfig, p: dict, x: jax.Array,
                      window: Optional[int] = None,
                      plan: Optional[SparsityPlan] = None,
                      q_capacity: Optional[int] = None,
                      kv_capacity: Optional[int] = None,
                      cache_len: Optional[int] = None,
                      backend: Optional[str] = None):
    """Full-sequence attention.  x: (B, L, D) -> (B, L, D).

    With ``cache_len`` set, also returns a right-padded KVCache (prefill);
    the cache always stores the compact (B, KV, S, Dh) layout.  ``backend``
    overrides ``cfg.attn_backend`` (see :mod:`repro.models.attn_backend`).
    """
    B, L, D = x.shape
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    mode = head_shard_mode(cfg)
    positions = jnp.broadcast_to(jnp.arange(L), (B, L))
    q, k, v = _project_qkv(cfg, p, x, positions, mode)

    name = resolve_backend(backend or cfg.attn_backend, cfg, L=L, plan=plan,
                           q_capacity=q_capacity)
    o = get_backend(name)(cfg, q, k, v, window=window, plan=plan,
                          q_capacity=q_capacity, kv_capacity=kv_capacity)

    out = _out_proj(cfg, p, o, mode)
    if cache_len is not None:
        kc = k.reshape(B, KV, G, L, Dh)[:, :, 0] if mode == "flat" else k
        vc = v.reshape(B, KV, G, L, Dh)[:, :, 0] if mode == "flat" else v
        pad = [(0, 0), (0, 0), (0, cache_len - L), (0, 0)]
        return out, KVCache(k=jnp.pad(kc, pad), v=jnp.pad(vc, pad))
    return out


def attention_decode(cfg: ArchConfig, p: dict, x: jax.Array, cache: KVCache,
                     pos: jax.Array, window: Optional[int] = None,
                     backend: Optional[str] = None):
    """One-token decode.  x: (B, 1, D); pos: (B,) current write index.

    Returns (out (B, 1, D), new_cache).  The cache is pre-allocated at
    max_len; masking handles both not-yet-written and out-of-window slots.
    Decode keeps the structured layout: the cache stays (B, KV, S, Dh) and
    scores shard over whatever the cache sharding chose (kv heads or seq).
    Dispatches through the decode side of the backend registry
    (``xla_dense_decode`` / ``pallas_flash_decode``).
    """
    B, _, D = x.shape
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    q, k_new, v_new = _project_qkv(cfg, p, x, pos[:, None], "structured")

    # per-row scatter of the new KV at `pos` (cheap: no full-cache math)
    upd = jax.vmap(
        lambda c, n, pb: jax.lax.dynamic_update_slice(c, n, (0, pb, 0)))
    k_all = upd(cache.k, k_new, pos)
    v_all = upd(cache.v, v_new, pos)

    name = resolve_backend(backend or cfg.attn_backend, cfg,
                           L=k_all.shape[2], decode=True)
    o = get_backend(name)(cfg, q[:, :, :, 0], k_all, v_all, pos=pos,
                          window=window)
    out = _out_proj(cfg, p, o[:, :, :, None], "structured")
    return out, KVCache(k=k_all, v=v_all)
