"""GQA attention with RoPE, qk-norm, sliding window, logit soft-capping,
KV-cache decode, and SPLS sparse execution.

Head layout & tensor parallelism.  Weights keep an explicit (KV, G)
structure (``G = n_heads // n_kv_heads`` query heads per KV group); at trace
time :func:`head_shard_mode` picks how heads bind to the mesh's model axis:

  * **structured** -- KV (or G) divides the model axis: shard that axis
    directly; attention einsums stay local (llama3 kv=8 < 16 shards G=16,
    gemma2/olmoe shard KV=16).
  * **flat** -- neither divides but H = KV*G does (h2o/dbrx/jamba/pixtral:
    kv=8, G<16, H%16==0): flatten heads, repeat the (small, replicated) KV
    heads locally per device -- no communication, each device materializes
    only its H/|model| KV copies.
  * **replicated** -- nothing divides (musicgen H=24): attention replicates
    over the model axis; TP still comes from FFN + vocab.  Noted in
    DESIGN.md.

Long sequences use a KV-chunked online-softmax scan (the flash-attention
recurrence in XLA) so scores never materialize at O(L^2); on real TPU the
Pallas kernel in ``repro.kernels.flash_attention`` replaces it 1:1.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.spls import SparsityPlan
from repro.core.sparse_exec import spls_attention, spls_attention_packed
from repro.sharding.logical import constrain
from .common import apply_rope, dense_init, rms_norm, rope_freqs, softcap

__all__ = ["init_attention", "attention_forward", "attention_decode",
           "KVCache", "init_kv_cache", "head_shard_mode"]

# KV-chunked attention kicks in above this length (keeps scores << O(L^2))
_CHUNK_THRESHOLD = 8192
_KV_CHUNK = 2048


class KVCache(NamedTuple):
    k: jax.Array          # (B, KV, S_max, Dh)
    v: jax.Array          # (B, KV, S_max, Dh)


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> KVCache:
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    z = jnp.zeros((batch, kv, max_len, dh), dtype)
    return KVCache(k=z, v=z)


def head_shard_mode(cfg: ArchConfig) -> str:
    """'structured' | 'flat' | 'replicated' -- see module docstring."""
    from repro.sharding.logical import _current_mesh
    mesh = _current_mesh()
    if mesh is None:
        return "structured"
    m = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    KV = cfg.n_kv_heads
    G = cfg.n_heads // max(KV, 1)
    if m <= 1 or KV % m == 0 or G % m == 0:
        return "structured"
    if cfg.n_heads % m == 0:
        return "flat"
    return "padded"


def _pad_heads_to(cfg: ArchConfig) -> int:
    """Padded head count for 'padded' mode: next multiple of |model|.

    Beyond-paper optimization (EXPERIMENTS.md §Perf, musicgen cell): when no
    head factorization divides the model axis (H=24 on 16), the projections
    are zero-padded to H'=32 *at trace time*.  Padded heads produce garbage
    attention outputs but their ``wo`` rows are zero, so the block output is
    bit-identical -- and attention compute/memory shards 16-way instead of
    replicating.
    """
    from repro.sharding.logical import _current_mesh
    mesh = _current_mesh()
    m = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    H = cfg.n_heads
    return -(-H // m) * m


def init_attention(cfg: ArchConfig, key: jax.Array, dtype) -> dict:
    D, KV, Dh = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, KV, G, Dh), dtype, fan_in=D),
        "wk": dense_init(ks[1], (D, KV, Dh), dtype, fan_in=D),
        "wv": dense_init(ks[2], (D, KV, Dh), dtype, fan_in=D),
        "wo": dense_init(ks[3], (KV, G, Dh, D), dtype, fan_in=KV * G * Dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((Dh,), dtype)
        p["k_norm"] = jnp.zeros((Dh,), dtype)
    return p


def _project_qkv(cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array,
                 mode: str = "structured"):
    """x (B, L, D) -> q (B, KV', G', L, Dh), k/v (B, KV', L, Dh).

    structured: KV' = KV, G' = G.   flat: KV' = H, G' = 1 (KV repeated).
    """
    B, L, D = x.shape
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    if mode in ("flat", "padded"):
        H = KV * G
        wq = p["wq"].reshape(D, H, Dh)
        wk, wv = p["wk"], p["wv"]
        if mode == "padded":
            Hp = _pad_heads_to(cfg)
            wq = jnp.pad(wq, ((0, 0), (0, Hp - H), (0, 0)))
            # pad KV to H' as well (each padded head attends independently)
            wk = jnp.pad(jnp.repeat(wk, G, axis=1),
                         ((0, 0), (0, Hp - H), (0, 0)))
            wv = jnp.pad(jnp.repeat(wv, G, axis=1),
                         ((0, 0), (0, Hp - H), (0, 0)))
            G = 1  # KV now per-head
        q = jnp.einsum("bld,dhe->bhle", x, wq)
        q = constrain(q, ("batch", "heads", "seq", None))
        k = jnp.einsum("bld,dkh->bklh", x, wk)
        v = jnp.einsum("bld,dkh->bklh", x, wv)
        if mode == "flat":
            k = jnp.repeat(k, G, axis=1)
            v = jnp.repeat(v, G, axis=1)
        k = constrain(k, ("batch", "heads", "seq", None))
        v = constrain(v, ("batch", "heads", "seq", None))
        q = q[:, :, None]  # (B, H', 1, L, Dh)
    else:
        q = jnp.einsum("bld,dkgh->bkglh", x, p["wq"])
        k = jnp.einsum("bld,dkh->bklh", x, p["wk"])
        v = jnp.einsum("bld,dkh->bklh", x, p["wv"])
        q = constrain(q, ("batch", "kv_heads", "qgroups", "seq", None))
        k = constrain(k, ("batch", "kv_heads", "seq", None))
        v = constrain(v, ("batch", "kv_heads", "seq", None))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    sin, cos = rope_freqs(positions, Dh, cfg.rope_theta)
    q = apply_rope(q, sin[:, None, None], cos[:, None, None])
    k = apply_rope(k, sin[:, None], cos[:, None])
    return q, k, v


def _out_proj(cfg: ArchConfig, p: dict, o: jax.Array, mode: str) -> jax.Array:
    """o (B, KV', G', L, Dh) -> (B, L, D)."""
    KV, Dh, D = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.d_model
    G = cfg.n_heads // KV
    if mode in ("flat", "padded"):
        wo = p["wo"].reshape(KV * G, Dh, D)
        if mode == "padded":
            Hp = _pad_heads_to(cfg)
            # zero wo rows for padded heads -> output bit-identical
            wo = jnp.pad(wo, ((0, Hp - KV * G), (0, 0), (0, 0)))
        out = jnp.einsum("bhld,hdm->blm", o[:, :, 0], wo)
    else:
        out = jnp.einsum("bkgld,kgdm->blm", o, p["wo"])
    return constrain(out, ("batch", "seq", "embed"))


def _band_mask(L: int, window: Optional[int], causal: bool) -> jax.Array:
    i = jnp.arange(L)[:, None]
    j = jnp.arange(L)[None, :]
    m = (j <= i) if causal else jnp.ones((L, L), bool)
    if window is not None:
        m = m & (i - j < window) & (j - i < (1 if causal else window))
    return m


def _dense_scores_attention(cfg, q, k, v, window, L):
    """Materialized-scores path for short L (cheap, single softmax)."""
    s = jnp.einsum("bkgqd,bkld->bkgql", q, k) * (q.shape[-1] ** -0.5)
    s = softcap(s, cfg.attn_softcap)
    m = _band_mask(L, window, cfg.causal)
    s = jnp.where(m, s, jnp.asarray(-1e30, s.dtype))
    a = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bkgql,bkld->bkgqd", a, v)


def _chunked_attention(cfg, q, k, v, window, L):
    """KV-chunked online-softmax (flash recurrence in XLA).

    Scans KV chunks; running (max, denom, acc) carry.  Memory is
    O(L * chunk) per head instead of O(L^2).  The Pallas kernel performs
    the true block skip on TPU; under lax.scan all chunks are computed.
    """
    B, KVp, Gp, Lq, Dh = q.shape
    C = _KV_CHUNK
    nC = L // C
    scale = Dh ** -0.5
    qi = jnp.arange(Lq)

    def body(carry, ck):
        m_run, l_run, acc = carry
        k_c, v_c, c0 = ck
        s = jnp.einsum("bkgqd,bkld->bkgql", q, k_c).astype(jnp.float32) * scale
        s = softcap(s, cfg.attn_softcap)
        kj = c0 + jnp.arange(C)
        mask = jnp.ones((Lq, C), bool)
        if cfg.causal:
            mask &= kj[None, :] <= qi[:, None]
        if window is not None:
            mask &= qi[:, None] - kj[None, :] < window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m_run, s.max(-1))
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None]) * mask.astype(jnp.float32)
        l_new = l_run * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgql,bkld->bkgqd", p.astype(v_c.dtype), v_c).astype(jnp.float32)
        return (m_new, l_new, acc), None

    kc = k.reshape(B, KVp, nC, C, Dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, KVp, nC, C, Dh).transpose(2, 0, 1, 3, 4)
    offs = jnp.arange(nC) * C
    init = (jnp.full((B, KVp, Gp, Lq), -1e30, jnp.float32),
            jnp.zeros((B, KVp, Gp, Lq), jnp.float32),
            jnp.zeros((B, KVp, Gp, Lq, Dh), jnp.float32))
    (m_f, l_f, acc), _ = jax.lax.scan(body, init, (kc, vc, offs))
    out = acc / jnp.maximum(l_f, 1e-9)[..., None]
    return out.astype(q.dtype)


def attention_forward(cfg: ArchConfig, p: dict, x: jax.Array,
                      window: Optional[int] = None,
                      plan: Optional[SparsityPlan] = None,
                      q_capacity: Optional[int] = None,
                      kv_capacity: Optional[int] = None,
                      cache_len: Optional[int] = None):
    """Full-sequence attention.  x: (B, L, D) -> (B, L, D).

    With ``cache_len`` set, also returns a right-padded KVCache (prefill);
    the cache always stores the compact (B, KV, S, Dh) layout.
    """
    B, L, D = x.shape
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    mode = head_shard_mode(cfg)
    positions = jnp.broadcast_to(jnp.arange(L), (B, L))
    q, k, v = _project_qkv(cfg, p, x, positions, mode)
    KVp, Gp = q.shape[1], q.shape[2]

    if plan is not None:
        from repro.core.spls_chunked import ChunkedPlan
        from repro.core.sparse_exec import spls_attention_chunked
        if isinstance(plan, ChunkedPlan):
            # long-sequence progressive path: packed + chunked, no O(L^2)
            o = spls_attention_chunked(
                q, k, v, plan, q_capacity or L, kv_capacity or L,
                Dh ** -0.5, cfg.attn_softcap, causal=cfg.causal)
        else:
            # SPLS path (simulation / capacity semantics); plan tensors
            # share the (KV', G') layout produced by build_block_plan.
            kr = jnp.broadcast_to(k[:, :, None], (B, KVp, Gp, L, Dh))
            vr = jnp.broadcast_to(v[:, :, None], (B, KVp, Gp, L, Dh))
            if q_capacity is not None and q_capacity < L:
                o = spls_attention_packed(q, kr, vr, plan, q_capacity,
                                          kv_capacity or L, Dh ** -0.5,
                                          cfg.attn_softcap)
            else:
                o = spls_attention(q, kr, vr, plan, Dh ** -0.5,
                                   cfg.attn_softcap)
    elif L > _CHUNK_THRESHOLD and L % _KV_CHUNK == 0:
        o = _chunked_attention(cfg, q, k, v, window, L)
    else:
        o = _dense_scores_attention(cfg, q, k, v, window, L)

    out = _out_proj(cfg, p, o, mode)
    if cache_len is not None:
        kc = k.reshape(B, KV, G, L, Dh)[:, :, 0] if mode == "flat" else k
        vc = v.reshape(B, KV, G, L, Dh)[:, :, 0] if mode == "flat" else v
        pad = [(0, 0), (0, 0), (0, cache_len - L), (0, 0)]
        return out, KVCache(k=jnp.pad(kc, pad), v=jnp.pad(vc, pad))
    return out


def attention_decode(cfg: ArchConfig, p: dict, x: jax.Array, cache: KVCache,
                     pos: jax.Array, window: Optional[int] = None):
    """One-token decode.  x: (B, 1, D); pos: (B,) current write index.

    Returns (out (B, 1, D), new_cache).  The cache is pre-allocated at
    max_len; masking handles both not-yet-written and out-of-window slots.
    Decode keeps the structured layout: the cache stays (B, KV, S, Dh) and
    scores shard over whatever the cache sharding chose (kv heads or seq).
    """
    B, _, D = x.shape
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.n_heads // KV
    q, k_new, v_new = _project_qkv(cfg, p, x, pos[:, None], "structured")

    # per-row scatter of the new KV at `pos` (cheap: no full-cache math)
    upd = jax.vmap(
        lambda c, n, pb: jax.lax.dynamic_update_slice(c, n, (0, pb, 0)))
    k_all = upd(cache.k, k_new, pos)
    v_all = upd(cache.v, v_new, pos)

    S = k_all.shape[2]
    s = jnp.einsum("bkgqd,bkld->bkgql", q, k_all) * (Dh ** -0.5)
    s = softcap(s, cfg.attn_softcap)
    j = jnp.arange(S)[None, :]
    m = j <= pos[:, None]
    if window is not None:
        m = m & (pos[:, None] - j < window)
    s = jnp.where(m[:, None, None, None, :], s, jnp.asarray(-1e30, s.dtype))
    a = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgql,bkld->bkgqd", a, v_all)
    out = _out_proj(cfg, p, o, "structured")
    return out, KVCache(k=k_all, v=v_all)
