"""FFN layers: dense (gated) MLP and capacity-based Mixture-of-Experts.

The MoE uses the einsum dispatch/combine formulation (Shazeer et al.): the
expert axis binds to the "model" mesh axis, so with pjit the dispatch einsum
lowers to an all-to-all-like collective schedule chosen by SPMD.  Capacity
is static (``cfg.moe_capacity``), tokens over capacity are dropped (their
FFN contribution is zero and the residual carries them) -- the same
static-shape discipline the SPLS capacity mode uses.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sharding.logical import constrain
from .common import Activations, dense_init

__all__ = ["init_mlp", "mlp_forward", "init_moe", "moe_forward", "init_ffn",
           "ffn_forward"]


# ---------------------------------------------------------------------------
# Dense (gated) MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ArchConfig, key: jax.Array, dtype) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (D, F), dtype, fan_in=D),
         "w_down": dense_init(ks[1], (F, D), dtype, fan_in=F)}
    if Activations.gated(cfg.ffn_activation):
        p["w_gate"] = dense_init(ks[2], (D, F), dtype, fan_in=D)
    return p


def mlp_forward(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    act = Activations.fn(cfg.ffn_activation)
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    if "w_gate" in p:
        up = up * act(jnp.einsum("...d,df->...f", x, p["w_gate"]))
    else:
        up = act(up)
    # NOTE: leading dim keeps its batch sharding -- a None entry in a
    # sharding constraint means *replicated*, not *unconstrained*.
    up = constrain(up, ("batch",) + (None,) * (up.ndim - 2) + ("ffn",))
    out = jnp.einsum("...f,fd->...d", up, p["w_down"])
    return constrain(out, ("batch",) + (None,) * (out.ndim - 2) + ("embed",))


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def init_moe(cfg: ArchConfig, key: jax.Array, dtype) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(key, 4)
    p = {"router": dense_init(ks[0], (D, E), jnp.float32, fan_in=D),
         "w_up": dense_init(ks[1], (E, D, F), dtype, fan_in=D),
         "w_down": dense_init(ks[2], (E, F, D), dtype, fan_in=F)}
    if Activations.gated(cfg.ffn_activation):
        p["w_gate"] = dense_init(ks[3], (E, D, F), dtype, fan_in=D)
    return p


def _dispatch_combine(probs: jax.Array, topk: int, capacity: int):
    """Top-k routing with per-expert capacity.

    probs: (B, L, E) router probabilities.  Returns
      dispatch: (B, L, E, C) one-hot-ish bool->dtype dispatch tensor
      combine:  (B, L, E, C) gate-weighted combine tensor
    """
    B, L, E = probs.shape
    gate_vals, experts = jax.lax.top_k(probs, topk)          # (B, L, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    onehot = jax.nn.one_hot(experts, E, dtype=jnp.int32)     # (B, L, K, E)
    # slot-major priority: slot k of token l gets position after all slots
    # k' < k of every token and all tokens l' < l at the same slot.
    slot_major = onehot.transpose(0, 2, 1, 3).reshape(B, topk * L, E)
    pos = jnp.cumsum(slot_major, axis=1) - slot_major        # positions before
    pos = pos.reshape(B, topk, L, E).transpose(0, 2, 1, 3)   # (B, L, K, E)
    within = (pos < capacity) & (onehot == 1)
    pos_in_e = (pos * onehot).sum(-1)                        # (B, L, K)

    cap_oh = jax.nn.one_hot(pos_in_e, capacity, dtype=probs.dtype)  # (B,L,K,C)
    keep = within.astype(probs.dtype)                        # (B, L, K, E)
    dispatch = jnp.einsum("blke,blkc->blec", keep, cap_oh)
    combine = jnp.einsum("blke,blk,blkc->blec", keep, gate_vals, cap_oh)
    return dispatch, combine


def moe_forward(cfg: ArchConfig, p: dict, x: jax.Array,
                capacity: Optional[int] = None) -> jax.Array:
    """x: (B, L, D) -> (B, L, D) through top-k experts."""
    B, L, D = x.shape
    E = cfg.moe_experts
    C = capacity or cfg.moe_capacity(L)
    act = Activations.fn(cfg.ffn_activation)

    logits = jnp.einsum("bld,de->ble", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    dispatch, combine = _dispatch_combine(probs, cfg.moe_topk, C)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)

    xin = jnp.einsum("blec,bld->becd", dispatch, x)
    xin = constrain(xin, ("batch", "experts", None, None))
    up = jnp.einsum("becd,edf->becf", xin, p["w_up"])
    if "w_gate" in p:
        up = up * act(jnp.einsum("becd,edf->becf", xin, p["w_gate"]))
    else:
        up = act(up)
    yout = jnp.einsum("becf,efd->becd", up, p["w_down"])
    yout = constrain(yout, ("batch", "experts", None, None))
    out = jnp.einsum("blec,becd->bld", combine, yout)
    return constrain(out, ("batch", "seq", "embed"))


def moe_aux_loss(probs: jax.Array, dispatch: jax.Array) -> jax.Array:
    """Load-balance auxiliary loss (Switch-style)."""
    # fraction of tokens dispatched to each expert vs mean router prob
    fe = dispatch.sum(-1).mean(axis=(0, 1))        # (E,)
    pe = probs.mean(axis=(0, 1))                   # (E,)
    return probs.shape[-1] * jnp.sum(fe * pe)


# ---------------------------------------------------------------------------
# Unified FFN entry
# ---------------------------------------------------------------------------

def init_ffn(cfg: ArchConfig, use_moe: bool, key: jax.Array, dtype) -> dict:
    return init_moe(cfg, key, dtype) if use_moe else init_mlp(cfg, key, dtype)


def ffn_forward(cfg: ArchConfig, use_moe: bool, p: dict,
                x: jax.Array) -> jax.Array:
    return moe_forward(cfg, p, x) if use_moe else mlp_forward(cfg, p, x)
