"""Logical-axis sharding: flax-style named axes decoupled from the mesh.

Model code annotates tensors with *logical* axis names ("batch", "embed",
"heads", ...).  The launcher installs a rule set mapping logical names to
mesh axes ("data", "model", "pod") for the current mesh; outside a mesh (or
with no rules installed) every annotation is a no-op, so the same model code
runs on a laptop CPU and on a 512-chip two-pod mesh unchanged.

Rules are divisibility-aware: a logical axis only binds to a mesh axis if the
dimension is divisible by the mesh-axis size, otherwise it silently degrades
to replicated -- this is what lets e.g. ``kv_heads=8`` coexist with a 16-way
model axis (the KV projections replicate, Q heads shard).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["axis_rules", "constrain", "logical_to_mesh", "spec_for",
           "current_rules", "named_sharding"]

MeshAxes = Union[str, Tuple[str, ...], None]

_state = threading.local()


def current_rules() -> Optional[Dict[str, MeshAxes]]:
    return getattr(_state, "rules", None)


def _current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, MeshAxes], mesh: Optional[Mesh] = None):
    """Install logical->mesh axis rules (and optionally the mesh itself)."""
    prev_rules = getattr(_state, "rules", None)
    prev_mesh = getattr(_state, "mesh", None)
    _state.rules = dict(rules)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = prev_rules
        _state.mesh = prev_mesh


def _axis_size(mesh: Optional[Mesh], axes: MeshAxes) -> int:
    if mesh is None or axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return size


def logical_to_mesh(names: Sequence[Optional[str]],
                    shape: Optional[Sequence[int]] = None,
                    rules: Optional[Dict[str, MeshAxes]] = None,
                    mesh: Optional[Mesh] = None) -> P:
    """Map logical axis names to a PartitionSpec under the active rules.

    ``shape`` (if given) enables the divisibility check: axes whose dim is
    not divisible by the bound mesh-axis size degrade to replicated.
    Duplicate mesh axes (two logical axes binding the same mesh axis) keep
    only the first binding.
    """
    rules = rules if rules is not None else current_rules()
    mesh = mesh if mesh is not None else _current_mesh()
    if rules is None:
        return P(*([None] * len(names)))
    used = set()
    out = []
    for i, n in enumerate(names):
        ax = rules.get(n) if n is not None else None
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        if any(a in used for a in axes):
            out.append(None)
            continue
        if shape is not None:
            sz = _axis_size(mesh, axes)
            if sz > 1 and shape[i] % sz != 0:
                out.append(None)
                continue
        used.update(axes)
        out.append(ax if isinstance(ax, str) else tuple(axes))
    return P(*out)


def spec_for(names: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
    return logical_to_mesh(names, shape)


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without rules/mesh."""
    rules = current_rules()
    mesh = _current_mesh()
    if rules is None or mesh is None:
        return x
    spec = logical_to_mesh(names, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, names: Sequence[Optional[str]],
                   shape: Optional[Sequence[int]] = None,
                   rules: Optional[Dict[str, MeshAxes]] = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_mesh(names, shape, rules, mesh))
