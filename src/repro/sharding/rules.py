"""Mesh-aware sharding rules for parameters, optimizer state, activations,
KV caches and input batches.

Parallelism layout (DESIGN.md):
  * ``data`` (x ``pod``)  -- pure data parallelism over the batch; gradients
    all-reduce over it.  The pod axis is just an outer data axis, so the
    multi-pod dry-run exercises cross-pod (DCI) gradient reduction.
  * ``model``             -- Megatron-style tensor parallelism: attention
    heads / FFN hidden / MoE experts / mamba inner channels / vocab.

Every binding is divisibility-guarded: a dimension that does not divide by
the mesh-axis size silently replicates (e.g. kv_heads=8 on a 16-way model
axis, or vocab=50280 on mamba2).  For *decode* shapes with tiny batches the
batch cannot shard, so the KV-cache sequence axis takes over the mesh axes
(flash-decode style sequence parallelism).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from .logical import logical_to_mesh

__all__ = ["activation_rules", "param_sharding", "cache_sharding",
           "batch_sharding", "opt_state_sharding", "DATA_AXES"]


def DATA_AXES(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def activation_rules(mesh: Mesh) -> Dict[str, Any]:
    """Logical -> mesh rules installed around model code."""
    return {
        "batch": DATA_AXES(mesh),
        "seq": None,
        # residual-stream activations saved at layer boundaries (the remat
        # checkpoints) are sequence-sharded over the model axis -- Megatron
        # sequence parallelism; cuts saved-activation memory by |model|.
        "act_seq": "model",
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "qgroups": "model",  # shards when kv_heads cannot (GQA, kv < |model|)
        "ffn": "model",
        "experts": "model",
        "vocab": "model",
        "inner": "model",
    }


# ---------------------------------------------------------------------------
# parameter shardings (path-pattern based)
# ---------------------------------------------------------------------------

def _param_logical(path_str: str, ndim: int, fsdp: bool):
    """Logical axes for one parameter leaf, by trailing name + rank.

    With ``fsdp`` every large weight also binds one non-TP dimension to the
    "fsdp" logical axis (the in-pod data axis): params + moments shard
    ZeRO-3 style and XLA all-gathers them at use, per scanned layer.
    """
    name = path_str.split("/")[-1]
    F = "fsdp" if fsdp else None
    table = {
        "embed": ("vocab", F),
        "lm_head": (F, "vocab"),
        "wq": (F, "kv_heads", "qgroups", None),
        "wk": (F, "kv_heads", None),
        "wv": (F, "kv_heads", None),
        "wo": ("kv_heads", "qgroups", None, F),
        "w_up": ("experts", F, "ffn") if ndim >= 4 else (F, "ffn"),
        "w_gate": ("experts", F, "ffn") if ndim >= 4 else (F, "ffn"),
        "w_down": ("experts", "ffn", F) if ndim >= 4 else ("ffn", F),
        "router": (None, None),
        "in_proj": (F, "inner"),
        "out_proj": ("inner", F),
        "conv_w": ("inner", None),
        "conv_b": ("inner",),
        "gate_norm": ("inner",),
    }
    names = table.get(name)
    if names is None:
        return (None,) * ndim  # norms, A_log, D, dt_bias, ... replicate
    # left-pad with None for the stacked period axis (and any extras)
    pad = ndim - len(names)
    return (None,) * pad + tuple(names)


def param_sharding(cfg: ArchConfig, mesh: Mesh, abstract_params: Any) -> Any:
    """NamedSharding pytree matching ``abstract_params``."""
    rules = activation_rules(mesh)
    rules["fsdp"] = "data"  # ZeRO shards stay inside a pod (no DCI gathers)
    rules["act_seq"] = "model"

    def assign(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        names = _param_logical(pstr, leaf.ndim, cfg.fsdp)
        spec = logical_to_mesh(names, leaf.shape, rules, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, abstract_params)


def opt_state_sharding(param_shardings: Any, opt_state_abstract: Any) -> Any:
    """Moments share their parameter's sharding; count replicates."""
    from repro.optim.adamw import OptState
    mesh = jax.tree.leaves(param_shardings)[0].mesh
    return OptState(
        count=NamedSharding(mesh, P()),
        mu=param_shardings,
        nu=param_shardings)


# ---------------------------------------------------------------------------
# batch + cache shardings
# ---------------------------------------------------------------------------

def batch_sharding(mesh: Mesh, global_batch: int) -> NamedSharding:
    """Batch axis over (pod, data) when divisible, else replicated."""
    axes = DATA_AXES(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    if global_batch % n == 0:
        return NamedSharding(mesh, P(axes))
    return NamedSharding(mesh, P())


def _shard_batch_or_seq(mesh: Mesh, batch: int, seq: int, head_div: bool,
                        batch_pos: int, head_pos: int, seq_pos: int,
                        ndim: int) -> P:
    """Decode-cache layout: prefer batch over data; spill seq when needed."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = DATA_AXES(mesh)
    n_data = 1
    for a in data_axes:
        n_data *= sizes[a]
    spec: list = [None] * ndim
    seq_axes = []
    if batch % n_data == 0 and batch >= n_data:
        spec[batch_pos] = data_axes if len(data_axes) > 1 else data_axes[0]
    else:
        seq_axes.extend(data_axes)  # tiny batch: give data axes to seq
    if head_div:
        spec[head_pos] = "model"
    else:
        seq_axes.append("model")
    if seq_axes:
        n_seq = 1
        for a in seq_axes:
            n_seq *= sizes[a]
        if seq % n_seq == 0:
            spec[seq_pos] = tuple(seq_axes) if len(seq_axes) > 1 else seq_axes[0]
    return P(*spec)


def cache_sharding(cfg: ArchConfig, mesh: Mesh, abstract_cache: Any,
                   batch: int, max_len: int) -> Any:
    """Shardings for the stacked decode cache pytree.

    KV tensors: (periods, B, KV, S, Dh); mamba conv: (periods, B, Ch, W);
    mamba ssd state: (periods, B, H, Pd, N).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes.get("model", 1)

    def assign(leaf):
        if leaf.ndim == 5 and leaf.shape[3] == max_len:      # KV cache
            kv_div = cfg.n_kv_heads % n_model == 0 and cfg.n_kv_heads >= n_model
            spec = _shard_batch_or_seq(mesh, batch, max_len, kv_div,
                                       batch_pos=1, head_pos=2, seq_pos=3,
                                       ndim=5)
        elif leaf.ndim == 4 and leaf.shape[2] == cfg.d_inner + 2 * cfg.ssm_state:
            # conv state: shard channels over model when divisible
            ch = leaf.shape[2]
            spec = P(None, None,
                     "model" if ch % n_model == 0 else None, None)
        elif leaf.ndim == 5:                                  # ssd state
            h = leaf.shape[2]
            spec = P(None, None,
                     "model" if h % n_model == 0 else None, None, None)
        else:
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree.map(assign, abstract_cache)
