"""Sharding: logical-axis rules + mesh-aware partition specs."""

from .logical import axis_rules, constrain, logical_to_mesh, named_sharding, spec_for
