"""Progressive (row-chunked) SPLS plan construction for long sequences.

The naive plan builder materializes the full PAM -- O(L^2) memory -- which
is fine at BERT scale but impossible at prefill_32k (a 32768^2 PAM per head
is 4 GiB).  The accelerator never materializes it either: the *progressive
generation scheme* (Sec. IV-C) predicts Q/attention/similarity one local
window at a time and starts formal generation as soon as a window's results
are ready.

This module is the XLA mapping of that scheme: the PAM is computed in row
blocks (a multiple of the similarity window w) under ``lax.scan``; each
block contributes
  * per-window critical/leader structure (similarity is *local*, so a row
    block that is a multiple of w is self-contained -- the whole reason the
    paper's local similarity beats global similarity in hardware),
  * its OR into the K/V column-keep mask,
  * its MFI votes for FFN sparsity.

What is intentionally dropped vs. the dense plan: the O(L^2) intra-row
top-k *mask*.  On the ASIC intra-row sparsity gates individual MACs; on a
TPU arbitrary per-element sparsity saves nothing (the MXU executes the full
tile), so the TPU-native execution keeps inter-row Q sparsity + KV column
sparsity + FFN token sparsity -- the structured parts -- and uses the
intra-row top-k only as the *detector* for columns and similarity, exactly
as derived in DESIGN.md §Hardware-adaptation.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .similarity import local_similarity
from .topk import topk_count

__all__ = ["ChunkedPlan", "chunked_plan_scan"]


class ChunkedPlan(NamedTuple):
    """Plan-lite for long-sequence execution (no O(L^2) mask).

    Leading head dims ``(B, KV', G')`` match the attention layout.
    """

    q_critical: jax.Array    # (B, KV', G', L) bool
    q_leader: jax.Array      # (B, KV', G', L) int32
    kv_keep: jax.Array       # (B, KV', G', L) bool
    ffn_critical: jax.Array  # (B, L) bool
    ffn_leader: jax.Array    # (B, L) int32


def chunked_plan_scan(qh: jax.Array, kh: jax.Array, *, k_ratio: float,
                      s_threshold: float, window: int, f_threshold: int,
                      row_block: int = 512, causal: bool = True,
                      scale: float | None = None,
                      head_names: Tuple = ("kv_heads", "qgroups")
                      ) -> ChunkedPlan:
    """Build the plan from predicted (already quantized) q/k heads.

    qh: (B, KV', G', L, Dh); kh: (B, KV', L, Dh).  Scans row blocks of the
    PAM; peak memory is O(row_block * L) per head instead of O(L^2).

    ``head_names``: logical axes of the two head dims, used to pin the PAM
    block's sharding inside the scan -- GSPMD otherwise *replicates* the
    ``top_k`` sort across batch AND heads (measured: a 200 TB/device
    all-gather on gemma2 prefill_32k; see EXPERIMENTS.md §Perf).
    """
    B, KVp, Gp, L, Dh = qh.shape
    assert L % row_block == 0 and row_block % window == 0, (L, row_block)
    nblk = L // row_block
    k = topk_count(L, k_ratio)
    scale = scale if scale is not None else Dh ** -0.5

    qb = qh.reshape(B, KVp, Gp, nblk, row_block, Dh).transpose(
        3, 0, 1, 2, 4, 5)  # (nblk, B, KV', G', R, Dh)
    offs = jnp.arange(nblk) * row_block

    from repro.sharding.logical import constrain  # no-op without rules
    blk_names = ("batch",) + head_names + (None, None)

    def body(kv_acc, inp):
        q_blk, r0 = inp                             # (B,KV',G',R,Dh)
        # PAM block in bf16: the prediction is already 8-bit-quantized
        # math, so bf16 storage halves plan-construction HBM traffic for
        # free (measured -40% on the memory roofline term).
        pam = (jnp.einsum("bkgqd,bkld->bkgql", q_blk, kh) * scale
               ).astype(jnp.bfloat16)
        pam = constrain(pam, blk_names)
        if causal:
            qi = r0 + jnp.arange(row_block)
            kj = jnp.arange(L)
            cmask = kj[None, :] <= qi[:, None]
            pam = jnp.where(cmask, pam, jnp.asarray(-3e38, pam.dtype))
        # threshold-based top-k via bisection: GSPMD replicates both sort
        # and scatter operands (a 200 TB/device all-gather at 32k each),
        # but counting compares partitions perfectly.  8 iterations pin
        # the k-th value to <1% of the value range; a few tie entries
        # more or less are harmless for column-keep and similarity.
        pam32 = pam.astype(jnp.float32)
        hi = pam32.max(-1, keepdims=True)
        # range must span only *valid* entries: the causal fill value would
        # otherwise eat every bisection step (-1e30 / 2^12 is still -2e26)
        lo = jnp.min(jnp.where(pam32 < -1e29, hi, pam32), -1, keepdims=True)
        for _ in range(12):
            mid = 0.5 * (lo + hi)
            cnt = (pam32 >= mid).sum(-1, keepdims=True)
            lo = jnp.where(cnt >= k, mid, lo)
            hi = jnp.where(cnt >= k, hi, mid)
        mask = pam32 >= lo
        mask = constrain(mask, blk_names)
        if causal:
            mask = mask & cmask
        spa = jnp.where(mask, pam32, jnp.zeros_like(pam32))
        spa = constrain(spa, blk_names)
        sim = local_similarity(spa, window, s_threshold)
        kv_acc = kv_acc | jnp.any(mask, axis=-2)
        # leaders are block-local -> lift to global row ids
        return kv_acc, (sim.is_critical, sim.leader + r0)

    kv0 = jnp.zeros((B, KVp, Gp, L), bool)
    kv_keep, (crit_b, lead_b) = jax.lax.scan(body, kv0, (qb, offs))
    # (nblk, B, KV', G', R) -> (B, KV', G', L)
    q_crit = crit_b.transpose(1, 2, 3, 0, 4).reshape(B, KVp, Gp, L)
    q_lead = lead_b.transpose(1, 2, 3, 0, 4).reshape(B, KVp, Gp, L)

    # MFI over all heads (votes on window-local offsets)
    from .mfi import mfi_ffn_sparsity
    leaders_h = q_lead.reshape(B, KVp * Gp, L)
    ffn = mfi_ffn_sparsity(leaders_h, window, f_threshold)
    return ChunkedPlan(q_critical=q_crit, q_leader=q_lead, kv_keep=kv_keep,
                       ffn_critical=ffn.is_critical, ffn_leader=ffn.leader)
