"""Progressive (row-chunked) SPLS plan construction for long sequences.

The naive plan builder materializes the full PAM -- O(L^2) memory -- which
is fine at BERT scale but impossible at prefill_32k (a 32768^2 PAM per head
is 4 GiB).  The accelerator never materializes it either: the *progressive
generation scheme* (Sec. IV-C) predicts Q/attention/similarity one local
window at a time and starts formal generation as soon as a window's results
are ready.

This module is the XLA mapping of that scheme: the PAM is computed in row
blocks (a multiple of the similarity window w) under ``lax.scan``; each
block contributes
  * per-window critical/leader structure (similarity is *local*, so a row
    block that is a multiple of w is self-contained -- the whole reason the
    paper's local similarity beats global similarity in hardware),
  * its OR into the K/V column-keep mask,
  * its MFI votes for FFN sparsity.

What is intentionally dropped vs. the dense plan: the O(L^2) intra-row
top-k *mask*.  On the ASIC intra-row sparsity gates individual MACs; on a
TPU arbitrary per-element sparsity saves nothing (the MXU executes the full
tile), so the TPU-native execution keeps inter-row Q sparsity + KV column
sparsity + FFN token sparsity -- the structured parts -- and uses the
intra-row top-k only as the *detector* for columns and similarity, exactly
as derived in DESIGN.md §Hardware-adaptation.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .similarity import local_similarity
from .topk import topk_count

__all__ = ["CAUSAL_FILL", "ChunkedPlan", "ChunkPlanBlock", "plan_chunk",
           "plan_chunk_votes", "bisect_topk_mask", "chunked_plan_scan",
           "votes_from_kv_any"]

# Causal / invalid-column fill for PAM blocks.  Must round-trip bfloat16
# (bf16 max is ~3.39e38) and sit far below any real predicted score so the
# bisection's lo-init can exclude it with a simple `< -1e29` test.
CAUSAL_FILL = -3e38


def bisect_topk_mask(pam32: jax.Array, k, n_iters: int = 12) -> jax.Array:
    """Threshold-based row-wise top-k via bisection on the last axis.

    GSPMD replicates both sort and scatter operands of an exact
    ``lax.top_k`` (a 200 TB/device all-gather at 32k each), but counting
    compares partitions perfectly.  ``n_iters`` halvings pin the k-th value
    to ``range / 2^n_iters`` (<0.03% of the value range at the default 12);
    a few tie entries more or less are harmless for column-keep and
    similarity.  ``k`` may be a traced scalar (unlike exact top-k, whose k
    must be static) -- this is what lets one serving jit cover every prompt
    length.  Fill entries (``< -1e29``, e.g. :data:`CAUSAL_FILL`) never pass
    the threshold and are excluded from the lo-init.
    """
    hi = pam32.max(-1, keepdims=True)
    # range must span only *valid* entries: the causal fill value would
    # otherwise eat every bisection step (-3e38 / 2^12 is still -7e34)
    lo = jnp.min(jnp.where(pam32 < -1e29, hi, pam32), -1, keepdims=True)
    for _ in range(n_iters):
        mid = 0.5 * (lo + hi)
        cnt = (pam32 >= mid).sum(-1, keepdims=True)
        lo = jnp.where(cnt >= k, mid, lo)
        hi = jnp.where(cnt >= k, hi, mid)
    return pam32 >= lo


class ChunkPlanBlock(NamedTuple):
    """Plan for one row block of the PAM, over a (possibly padded) column
    buffer of ``S`` slots.  Leading dims ``(B, KV', G')``; ``C`` rows.

    This is the streaming unit the serving engine consumes: one of these is
    produced per prefill chunk (O(C * S) memory -- never the full PAM), and
    its ``kv_any`` contributions OR-accumulate across chunks into the
    page-prune vote (:func:`votes_from_kv_any`).
    """

    mask: jax.Array          # (B, KV', G', C, S) bool intra-row SPA mask
    q_critical: jax.Array    # (B, KV', G', C) bool
    q_leader: jax.Array      # (B, KV', G', C) int32 *global* row ids
    kv_any: jax.Array        # (B, KV', G', S) bool: this block's column OR
    ffn_critical: jax.Array  # (B, C) bool
    ffn_leader: jax.Array    # (B, C) int32 global row ids


def _block_pam_mask(qh_blk: jax.Array, kh: jax.Array, *, k, row0,
                    n_valid_rows, n_cols, causal: bool,
                    scale: Optional[float],
                    col_live: Optional[jax.Array] = None,
                    constrain_names: Optional[Tuple] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Shared PAM-block -> top-k mask stage of :func:`plan_chunk` (also
    used standalone by :func:`plan_chunk_votes`).  Returns
    ``(mask (B,KV',G',C,S), pam32)``.

    ``col_live`` (S,) bool marks columns finalized as pruned by the
    horizon vote (:mod:`repro.core.planner`): dead columns are filled like
    causal/invalid ones, so they can neither win a top-k slot nor receive
    further keep votes.  ``constrain_names`` threads the GSPMD sharding
    hint the long-sequence scan driver needs (a no-op without rules).
    """
    Dh = qh_blk.shape[-1]
    C = qh_blk.shape[-2]
    S = kh.shape[-2]
    scale = scale if scale is not None else Dh ** -0.5
    # PAM block in bf16: the prediction is already 8-bit-quantized math, so
    # bf16 storage halves plan-construction HBM traffic for free.
    pam = (jnp.einsum("bkgqd,bkld->bkgql", qh_blk, kh) * scale
           ).astype(jnp.bfloat16)
    if constrain_names is not None:
        from repro.sharding.logical import constrain
        pam = constrain(pam, constrain_names)
    qi = row0 + jnp.arange(C)                       # global row positions
    kj = jnp.arange(S)                              # column slot == position
    cmask = kj[None, :] < n_cols
    if causal:
        cmask = cmask & (kj[None, :] <= qi[:, None])
    if col_live is not None:
        cmask = cmask & col_live[None, :]
    pam = jnp.where(cmask, pam, jnp.asarray(CAUSAL_FILL, pam.dtype))
    pam32 = pam.astype(jnp.float32)
    valid_rows = (jnp.arange(C) < n_valid_rows)
    mask = bisect_topk_mask(pam32, k)
    if constrain_names is not None:
        from repro.sharding.logical import constrain
        mask = constrain(mask, constrain_names)
    mask = mask & cmask & valid_rows[:, None]
    return mask, pam32


def plan_chunk_votes(qh_blk: jax.Array, kh: jax.Array, *, k, row0,
                     n_valid_rows, n_cols, causal: bool = True,
                     scale: Optional[float] = None,
                     col_live: Optional[jax.Array] = None) -> jax.Array:
    """Column-keep contribution only: ``(B, KV', G', S)`` bool.

    The page-prune vote needs just the zero-column detection, not the
    similarity structure -- skipping the windowed-L1 stage keeps the vote
    path's peak at the O(C * S) mask block (the pairwise-distance tensor
    is O(heads * C * window * S), the largest intermediate of a full plan
    block)."""
    mask, _ = _block_pam_mask(qh_blk, kh, k=k, row0=row0,
                              n_valid_rows=n_valid_rows, n_cols=n_cols,
                              causal=causal, scale=scale, col_live=col_live)
    return jnp.any(mask, axis=-2)


def plan_chunk(qh_blk: jax.Array, kh: jax.Array, *, k, row0,
               n_valid_rows, n_cols, s_threshold: float, window: int,
               f_threshold: int, causal: bool = True,
               scale: Optional[float] = None,
               col_live: Optional[jax.Array] = None,
               constrain_names: Optional[Tuple] = None) -> ChunkPlanBlock:
    """SPLS plan for a single row block -- the progressive-generation unit.

    qh_blk: (B, KV', G', C, Dh) predicted q heads for rows
    ``row0 .. row0+C``; kh: (B, KV', S, Dh) predicted k heads for every
    column slot seen so far (slot index == original position in the
    unpruned streaming layout).  ``k`` (top-k count), ``row0``,
    ``n_valid_rows`` (real rows in this block; the tail may be padding) and
    ``n_cols`` (valid columns) may all be traced scalars, so a single jit
    of this function serves every prompt length and every chunk.

    ``row0`` must be a multiple of ``window`` and C a window multiple: the
    similarity windows are then exactly the windows the unchunked pipeline
    would form, which is what makes the result independent of the chunking
    (the paper's locality argument, pinned by the row-block invariance
    tests).  Padded rows are never critical and never lead; padded/future
    columns are filled with :data:`CAUSAL_FILL` and never voted for.
    """
    B, KVp, Gp, C, Dh = qh_blk.shape
    S = kh.shape[-2]
    mask, pam32 = _block_pam_mask(qh_blk, kh, k=k, row0=row0,
                                  n_valid_rows=n_valid_rows, n_cols=n_cols,
                                  causal=causal, scale=scale,
                                  col_live=col_live,
                                  constrain_names=constrain_names)
    spa = jnp.where(mask, pam32, jnp.zeros_like(pam32))
    if constrain_names is not None:
        from repro.sharding.logical import constrain
        spa = constrain(spa, constrain_names)
    sim = local_similarity(spa, window, s_threshold,
                           valid_len=n_valid_rows)
    leader = sim.leader + row0                      # block-local -> global
    kv_any = jnp.any(mask, axis=-2)

    from .mfi import mfi_ffn_sparsity
    leaders_h = sim.leader.reshape(B, KVp * Gp, C)  # block-local for MFI
    ffn = mfi_ffn_sparsity(leaders_h, window, f_threshold)
    return ChunkPlanBlock(mask=mask, q_critical=sim.is_critical,
                          q_leader=leader, kv_any=kv_any,
                          ffn_critical=ffn.is_critical,
                          ffn_leader=ffn.leader + row0)


def votes_from_kv_any(kv_any: jax.Array) -> jax.Array:
    """(B, KV', G', S) per-head column-keep bools -> (S,) head-vote counts.

    The cross-chunk accumulator is a plain OR over blocks *per head* (a
    head's "any row selected this column" can only turn True as more chunks
    arrive), after which the vote is the head count -- summing per-block
    votes instead would double-count heads across chunks.
    """
    B = kv_any.shape[0]
    S = kv_any.shape[-1]
    return kv_any.reshape(B, -1, S).sum(axis=1).astype(jnp.int32)[0]


class ChunkedPlan(NamedTuple):
    """Plan-lite for long-sequence execution (no O(L^2) mask).

    Leading head dims ``(B, KV', G')`` match the attention layout.
    """

    q_critical: jax.Array    # (B, KV', G', L) bool
    q_leader: jax.Array      # (B, KV', G', L) int32
    kv_keep: jax.Array       # (B, KV', G', L) bool
    ffn_critical: jax.Array  # (B, L) bool
    ffn_leader: jax.Array    # (B, L) int32


def chunked_plan_scan(qh: jax.Array, kh: jax.Array, *, k_ratio: float,
                      s_threshold: float, window: int, f_threshold: int,
                      row_block: int = 512, causal: bool = True,
                      scale: float | None = None,
                      head_names: Tuple = ("kv_heads", "qgroups")
                      ) -> ChunkedPlan:
    """Build the plan from predicted (already quantized) q/k heads.

    qh: (B, KV', G', L, Dh); kh: (B, KV', L, Dh).  Scans row blocks of the
    PAM; peak memory is O(row_block * L) per head instead of O(L^2).

    ``head_names``: logical axes of the two head dims, used to pin the PAM
    block's sharding inside the scan -- GSPMD otherwise *replicates* the
    ``top_k`` sort across batch AND heads (measured: a 200 TB/device
    all-gather on gemma2 prefill_32k; see EXPERIMENTS.md §Perf).
    """
    B, KVp, Gp, L, Dh = qh.shape
    assert L % row_block == 0 and row_block % window == 0, (L, row_block)
    nblk = L // row_block
    k = topk_count(L, k_ratio)

    qb = qh.reshape(B, KVp, Gp, nblk, row_block, Dh).transpose(
        3, 0, 1, 2, 4, 5)  # (nblk, B, KV', G', R, Dh)
    offs = jnp.arange(nblk) * row_block
    blk_names = ("batch",) + head_names + (None, None)

    # one scan step == one progressive plan block: the same primitive the
    # serving chunk step and the full-sequence progressive assembly drive
    # (repro.core.planner), so the three paths cannot drift.  Only the
    # plan-lite fields leave the scan -- the O(row_block * L) mask block
    # stays transient (never stacked into an O(L^2) tensor).  MFI is
    # window-local and row blocks are window multiples, so the per-block
    # FFN structure concatenates into exactly the global vote.
    def body(kv_acc, inp):
        q_blk, r0 = inp                             # (B,KV',G',R,Dh)
        pb = plan_chunk(q_blk, kh, k=k, row0=r0, n_valid_rows=row_block,
                        n_cols=L, s_threshold=s_threshold, window=window,
                        f_threshold=f_threshold, causal=causal, scale=scale,
                        constrain_names=blk_names)
        return kv_acc | pb.kv_any, (pb.q_critical, pb.q_leader,
                                    pb.ffn_critical, pb.ffn_leader)

    kv0 = jnp.zeros((B, KVp, Gp, L), bool)
    kv_keep, (crit_b, lead_b, fcrit_b, flead_b) = jax.lax.scan(
        body, kv0, (qb, offs))
    # (nblk, B, KV', G', R) -> (B, KV', G', L)
    q_crit = crit_b.transpose(1, 2, 3, 0, 4).reshape(B, KVp, Gp, L)
    q_lead = lead_b.transpose(1, 2, 3, 0, 4).reshape(B, KVp, Gp, L)
    ffn_crit = fcrit_b.transpose(1, 0, 2).reshape(B, L)
    ffn_lead = flead_b.transpose(1, 0, 2).reshape(B, L)
    return ChunkedPlan(q_critical=q_crit, q_leader=q_lead, kv_keep=kv_keep,
                       ffn_critical=ffn_crit, ffn_leader=ffn_lead)
