"""Attention prediction: build the Predicted Attention Matrix (PAM).

ESACT predicts attention *before* the formal QKV generation (Fig. 5a): the
int8 embeddings X and the int8 weights W_Q, W_K are HLog-quantized, the
predicted Q'/K' are formed with shift-add arithmetic, re-quantized to 8 bits,
HLog-quantized again, and multiplied to produce the PAM.  Everything here is
the pure-JAX realisation of that pipeline; the Pallas kernel in
``repro.kernels.hlog_qmatmul`` fuses the two quantized matmuls for the
TPU-native path.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .quantizers import quantize_dequantize

__all__ = ["predict_qk", "predict_qk_pre", "predicted_attention",
           "split_heads"]


def split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    """(..., L, D) -> (..., H, L, Dh)."""
    *lead, L, D = x.shape
    if D % n_heads:
        raise ValueError(f"D={D} not divisible by n_heads={n_heads}")
    return x.reshape(*lead, L, n_heads, D // n_heads).swapaxes(-2, -3)


def predict_qk(x: jax.Array, wq: jax.Array, wk: jax.Array,
               method: str = "hlog", bits: int = 8,
               act_axis: Optional[int] = None):
    """Predict Q and K with log-domain quantized inputs and weights.

    Args:
      x:  (..., L, D) activations (float; int8-QAT values in the paper).
      wq, wk: (D, D_qk) projection weights.
      act_axis: quantization-scale axis for the *activations* (and the
        second-stage Q/K re-quantization).  ``None`` (default) keeps the
        per-tensor scale; ``-1`` gives per-token scales, which makes every
        row of the prediction independent of every other row -- required by
        the streaming serving predictor, where tokens arrive one chunk at a
        time and future rows must not influence already-emitted scales.
        Weights always use per-tensor scales (they are static).

    Returns ``(q_pred, k_pred)`` of shape (..., L, D_qk), re-quantized to
    8-bit + projected again, ready for the score matmul -- this mirrors the
    "additional 8-bit quantization ... and the entire process is repeated"
    step of Sec. IV-B.
    """
    q_pred, k_pre = predict_qk_pre(x, wq, wk, method, bits, act_axis)
    # second-stage quantization of the predicted K
    k_pred = quantize_dequantize(k_pre, method, bits, axis=act_axis)
    return q_pred, k_pred


def predict_qk_pre(x: jax.Array, wq: jax.Array, wk: jax.Array,
                   method: str = "hlog", bits: int = 8,
                   act_axis: Optional[int] = None):
    """Prediction up to (but excluding) K's second-stage re-quantization.

    Returns ``(q_pred, k_pre)``: ``q_pred`` fully quantized as in
    :func:`predict_qk`; ``k_pre`` the predicted K *before* its
    second-stage quantize-dequantize.  This is the seam the unified
    planner's int8 predictor-cache encoder shares with :func:`predict_qk`
    (:meth:`repro.core.planner.PlanContext.encode_pred_qk` symmetric-
    quantizes ``k_pre`` into codes; decoding projects the codes back --
    bit-for-bit ``quantize_dequantize(k_pre, ...)``), so the two paths
    cannot drift.
    """
    xq = quantize_dequantize(x, method, bits, axis=act_axis)
    q_pred = xq @ quantize_dequantize(wq, method, bits)
    k_pre = xq @ quantize_dequantize(wk, method, bits)
    q_pred = quantize_dequantize(q_pred, method, bits, axis=act_axis)
    return q_pred, k_pre


def predicted_attention(x: jax.Array, wq: jax.Array, wk: jax.Array,
                        n_heads: int, method: str = "hlog", bits: int = 8,
                        causal: bool = False, scale: Optional[float] = None,
                        n_kv_heads: Optional[int] = None) -> jax.Array:
    """Full PAM: (..., H, L, L) predicted scores (pre-softmax).

    ``causal=True`` masks the strict upper triangle to ``-inf`` substitute
    (a large negative) so top-k never selects future positions for decoder
    models.  For GQA (``n_kv_heads < n_heads``) the predicted K heads are
    broadcast across their query group, giving a per-*query*-head PAM.
    """
    qp, kp = predict_qk(x, wq, wk, method, bits)
    qh = split_heads(qp, n_heads)
    n_kv = n_kv_heads or n_heads
    kh = split_heads(kp, n_kv)
    if n_kv != n_heads:
        kh = jnp.repeat(kh, n_heads // n_kv, axis=-3)
    dh = qh.shape[-1]
    s = scale if scale is not None else 1.0 / jnp.sqrt(jnp.asarray(dh, qh.dtype))
    pam = jnp.einsum("...hqd,...hkd->...hqk", qh, kh) * s
    if causal:
        L = pam.shape[-1]
        neg = jnp.asarray(jnp.finfo(pam.dtype).min / 2, pam.dtype)
        tri = jnp.tril(jnp.ones((L, L), dtype=bool))
        pam = jnp.where(tri, pam, neg)
    return pam
