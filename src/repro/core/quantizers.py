"""Quantizers used by the SPLS sparsity-prediction pipeline.

The paper (ESACT, Sec. III-A) predicts the attention matrix *before* the
formal QKV generation, using aggressively quantized inputs/weights.  Three
log-domain quantizers are compared:

* **PoT**  -- power-of-two levels ``{2^m}``; cheap (leading-one detect) but
  large projection error for big magnitudes.
* **APoT** -- additive powers-of-two (a=2), levels ``{2^i + 2^j, i > j}``;
  accurate but level-dense, and on real hardware its irregular level set
  forces adder-tree accumulation.
* **HLog** -- the paper's hybrid: powers of two plus their *intermediate
  averages*, eq. (1): ``{2^0, 2^1, 2^0+2^1, 2^2, ..., 2^{n-2},
  2^{n-3}+2^{n-2}, 2^{n-1}}`` i.e. ``{2^m} U {1.5 * 2^m}``.  Ties project to
  the *higher* level.

All quantizers here operate on **integer magnitudes** obtained from an 8-bit
symmetric pre-quantization (the paper quantizes all linear weights to int8
first) and return *dequantized* values on the original scale, so the rest of
the prediction pipeline is plain arithmetic on floats.

Hardware note (DESIGN.md "hardware adaptation"): the paper's bit-level shift
detector / shift-judgment array replaces multiplications with additions on an
ASIC.  A TPU has no scalar shift-add datapath that beats the MXU, so the
TPU-native realisation keeps the *numerics* of HLog (the projection below is
bit-exact w.r.t. the SD unit, see ``hlog_bitlevel_*``) and maps the product
onto an int8/bf16 MXU matmul of the dequantized codes -- the win on TPU is
doing the *prediction* at low precision on tiny matrices, not avoiding
multipliers.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "symmetric_quantize",
    "hlog_levels",
    "pot_levels",
    "apot_levels",
    "project_to_levels",
    "hlog_project",
    "pot_project",
    "apot_project",
    "hlog_bitlevel_encode",
    "hlog_bitlevel_decode",
    "hlog_bitlevel_project",
    "quantize_dequantize",
]


# ---------------------------------------------------------------------------
# 8-bit symmetric pre-quantization
# ---------------------------------------------------------------------------

def symmetric_quantize(x: jax.Array, bits: int = 8, axis=None,
                       eps: float = 1e-8) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor (or per-``axis``) quantization.

    Returns ``(q, scale)`` with ``q`` integer-valued (stored as float32 for
    downstream arithmetic) in ``[-(2^{bits-1}-1), 2^{bits-1}-1]`` and
    ``x ~= q * scale``.
    """
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, eps) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q, scale


# ---------------------------------------------------------------------------
# Level sets
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def hlog_levels(bits: int = 8) -> np.ndarray:
    """HLog magnitude levels, eq. (1) of the paper.

    ``{2^m : m=0..bits-1} U {1.5 * 2^m : m=1..bits-2}``; sorted ascending.
    For bits=8: [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128].
    """
    singles = [2.0 ** m for m in range(bits)]
    sums = [2.0 ** (m - 1) + 2.0 ** m for m in range(1, bits - 1)]
    return np.array(sorted(singles + sums), dtype=np.float64)


@functools.lru_cache(maxsize=None)
def pot_levels(bits: int = 8) -> np.ndarray:
    """Power-of-two magnitude levels ``{2^m : m = 0..bits-1}``."""
    return np.array([2.0 ** m for m in range(bits)], dtype=np.float64)


@functools.lru_cache(maxsize=None)
def apot_levels(bits: int = 8) -> np.ndarray:
    """Additive-PoT (a=2) magnitude levels ``{2^i} U {2^i + 2^j, i > j}``."""
    lv = set()
    for i in range(bits):
        lv.add(2.0 ** i)
        for j in range(i):
            lv.add(2.0 ** i + 2.0 ** j)
    return np.array(sorted(lv), dtype=np.float64)


# ---------------------------------------------------------------------------
# Generic projection (nearest level, ties -> higher level)
# ---------------------------------------------------------------------------

def project_to_levels(mag: jax.Array, levels: np.ndarray) -> jax.Array:
    """Project non-negative magnitudes onto ``levels`` (nearest; tie -> up).

    Magnitudes below the smallest level / 2 (exclusive) round to zero only
    when exactly 0; the paper pre-quantizes to ints >= 1 so sub-level inputs
    do not occur, but we handle them by clamping to the nearest level.
    Zero stays zero.
    """
    lv = jnp.asarray(levels, dtype=mag.dtype)
    # midpoints between consecutive levels; value >= midpoint -> upper level
    mids = (lv[:-1] + lv[1:]) / 2.0
    idx = jnp.searchsorted(mids, mag, side="right")  # tie (== mid) -> upper
    proj = lv[idx]
    return jnp.where(mag == 0, jnp.zeros_like(proj), proj)


def _signed_project(x: jax.Array, levels: np.ndarray) -> jax.Array:
    return jnp.sign(x) * project_to_levels(jnp.abs(x), levels)


def hlog_project(x: jax.Array, bits: int = 8) -> jax.Array:
    """Signed HLog projection of integer-valued ``x``."""
    return _signed_project(x, hlog_levels(bits))


def pot_project(x: jax.Array, bits: int = 8) -> jax.Array:
    return _signed_project(x, pot_levels(bits))


def apot_project(x: jax.Array, bits: int = 8) -> jax.Array:
    return _signed_project(x, apot_levels(bits))


# ---------------------------------------------------------------------------
# Bit-level HLog (the Shift Detector of Sec. IV-B), bit-exact vs. projection
# ---------------------------------------------------------------------------

def hlog_bitlevel_encode(x: jax.Array, bits: int = 8) -> jax.Array:
    """Bit-level Shift-Detector encoding of integer-valued ``x``.

    Mirrors Fig. 12: find the leading one of the magnitude, inspect the next
    two bits ``b1 b0`` and emit a 5-bit code ``[sign | exp(3) | form(1)]``:

      * ``b1 b0 = 00``            -> ``2^m``          (form=0, exp=m)
      * ``b1 b0 = 01`` or ``10``  -> ``1.5 * 2^m``    (form=1, exp=m)
      * ``b1 b0 = 11``            -> ``2^{m+1}``      (form=0, exp=m+1)

    ``form = b1 XOR b0``; ``exp = m + (b1 AND b0)`` -- exactly the XOR/OR
    gate pair of the SD unit.  Encoded as an int32 ``sign*2^4 + exp*2 + form``
    with the convention exp occupies 3 bits for bits=8 (m+1 <= 7... m+1 can
    be 8 for inputs >= 224; we keep exp as a plain integer field here; the
    5-bit packing in RTL caps inputs at int8 so exp <= 7 never overflows for
    |x| <= 127 except 112..127 -> exp 7, fine).

    Special case m=0 (|x| == 1): next bits are zero -> code ``2^0``.
    Zero encodes to the all-zero code with form=0 exp=0 sign=0 and must be
    masked by the caller (we return -1 in the exp field sentinel-free; decode
    handles it via the stored zero flag bit packed at bit 5).
    """
    mag = jnp.abs(x).astype(jnp.int32)
    sign = (x < 0).astype(jnp.int32)
    is_zero = (mag == 0)
    safe = jnp.maximum(mag, 1)
    # leading-one position m = floor(log2(mag))
    m = (31 - jax.lax.clz(safe)).astype(jnp.int32)
    b1 = (safe >> jnp.maximum(m - 1, 0)) & 1
    b1 = jnp.where(m >= 1, b1, 0)
    b0 = (safe >> jnp.maximum(m - 2, 0)) & 1
    b0 = jnp.where(m >= 2, b0, 0)
    form = b1 ^ b0
    exp = m + (b1 & b0)
    # m=0 can only be |x|==1 -> form 0 exp 0 (b1=b0=0 already ensures this)
    code = (sign << 4) | (exp << 1) | form
    code = jnp.where(is_zero, jnp.full_like(code, 1 << 5), code)  # zero flag
    return code


def hlog_bitlevel_decode(code: jax.Array) -> jax.Array:
    """Decode SD codes back to signed dequantized values (float32)."""
    is_zero = (code >> 5) & 1
    sign = (code >> 4) & 1
    exp = (code >> 1) & 7
    form = code & 1
    val = jnp.exp2(exp.astype(jnp.float32)) * (1.0 + 0.5 * form.astype(jnp.float32))
    val = jnp.where(sign == 1, -val, val)
    return jnp.where(is_zero == 1, jnp.zeros_like(val), val)


def hlog_bitlevel_project(x: jax.Array, bits: int = 8) -> jax.Array:
    """Encode+decode; bit-exact equal to :func:`hlog_project` on integers."""
    return hlog_bitlevel_decode(hlog_bitlevel_encode(x, bits))


# ---------------------------------------------------------------------------
# Convenience: float -> int8 -> log-domain -> dequantized float
# ---------------------------------------------------------------------------

_PROJECTORS = {
    "hlog": hlog_project,
    "hlog_bitlevel": hlog_bitlevel_project,
    "pot": pot_project,
    "apot": apot_project,
    "none": lambda q, bits=8: q,
}


def quantize_dequantize(x: jax.Array, method: str = "hlog", bits: int = 8,
                        axis=None) -> jax.Array:
    """Full prediction-path quantization: int8 symmetric then log projection.

    Returns float values on the original scale of ``x``.
    """
    if method not in _PROJECTORS:
        raise ValueError(f"unknown quantization method {method!r}; "
                         f"expected one of {sorted(_PROJECTORS)}")
    q, scale = symmetric_quantize(x, bits=bits, axis=axis)
    return _PROJECTORS[method](q, bits) * scale
