"""Unified SPLS planner: one subsystem behind every execution mode.

Before this module, SPLS planning had forked into three near-copies --
the exact dense plan (``models.blocks.build_block_plan``), the
progressive row-block scan (``models.blocks.build_block_plan_chunked`` +
``core.spls_chunked.chunked_plan_scan``), and the streaming serving plan
inlined in ``serving.paged_model.paged_prefill_chunk_spls``.  Each new
sparse-compute feature could only land on one of them.  This module
collapses the forks: :class:`PlanContext` owns the quantized predictor
state (head layouts, HLog quantization, the int8 code encoding of the
paged predictor cache), window-aligned vote accumulation, and plan
emission; the execution modes are thin drivers over it:

* **simulation / training** -- :meth:`PlanContext.plan_exact` (the
  offline exact-top-k plan) and :meth:`PlanContext.plan_progressive`
  (streaming-reproducible numerics over the full sequence), both reached
  through ``models.blocks.block_forward``;
* **progressive long-sequence** -- :meth:`PlanContext.plan_scan`, the
  ``lax.scan`` row-block driver (O(row_block * L) peak, never a full
  PAM);
* **streaming serving** -- :meth:`PlanContext.encode_pred_qk` /
  :meth:`PlanContext.decode_pred_k` / :meth:`PlanContext.plan_block`,
  driven one chunk at a time by
  ``serving.paged_model.paged_prefill_chunk_spls``.

All three emit *identical plans on identical predicted heads* (the
``plan_block`` primitive in :mod:`repro.core.spls_chunked` is shared;
pinned by ``tests/test_planner.py``).

**Horizon-finalized column votes.**  The cross-head column-keep vote is
monotone in rows: a head's "some row selected this column" bit only ever
turns on as chunks arrive, so the cross-head agreement bar
(``ceil(spls_prune_vote * H)`` heads, ``keep_from_votes``) is sticky
once won.  Waiting for the last chunk reproduces the full-prefill vote
exactly (``vote_horizon=None``), but forces every chunk row's K/V to
materialize.  A finite ``vote_horizon = h`` finalizes a column as
**pruned** once it has been votable for ``h`` consecutive chunks while
still below that same bar.  Finalized columns are denied
materialization and attention, but the prediction/vote pipeline itself
stays horizon-independent (dead columns still occupy their top-k
candidacy) -- the vote trajectory matches the end-of-prefill path's, so
a larger horizon can only rescue columns, never lose them (the
monotonicity the tests pin).  With ``h == 1`` the
decision for a chunk's *own* columns lands before formal K/V generation
(prediction precedes QKV -- the paper's Fig. 5a ordering), so the K/V
projection itself runs packed over only the surviving columns
(:func:`repro.sparse_compute.packed.packed_project_kv`).  Finite
horizons trade bounded divergence (a later row that *would* have voted
for a finalized column is denied it) for K/V projection FLOPs and
earlier page frees; ``None`` is bit-for-bit today's end-of-prefill vote.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .quantizers import _PROJECTORS, symmetric_quantize
from .spls import SPLSConfig, SparsityPlan
from .spls_chunked import (ChunkedPlan, ChunkPlanBlock, chunked_plan_scan,
                           plan_chunk, plan_chunk_votes, votes_from_kv_any)
from .topk import topk_count

__all__ = [
    "PlanContext", "build_block_plan", "build_block_plan_chunked",
    "build_block_plan_progressive", "progressive_plan_blocks",
    "own_column_keep", "pack_within_capacity", "horizon_update_live",
    "votes_from_kv_any",
]


def _progressive_row_block(L: int, w: int) -> int:
    """Row-block size for the progressive drivers: a window multiple, at
    most ~512 rows (the PAM block is O(row_block * L) per head)."""
    return max(w, (min(512, L) // w) * w)


@dataclasses.dataclass(frozen=True)
class PlanContext:
    """Static planning context: SPLS hyper-parameters + head layout.

    The single owner of how activations become predicted heads (which
    quantization axis, which (KV', G') layout) and how plan blocks are
    emitted from them.  Build one per (config, shard-mode) with
    :meth:`for_config`; every driver below is a method so the paths can
    never drift apart.
    """

    scfg: SPLSConfig
    D: int
    KV: int
    G: int
    Dh: int
    causal: bool
    mode: str = "structured"     # head layout: structured | flat

    @classmethod
    def for_config(cls, cfg, mode: Optional[str] = None) -> "PlanContext":
        if mode is None:
            from repro.models.attention import head_shard_mode
            mode = head_shard_mode(cfg)
        scfg = cfg.spls
        if scfg.causal != cfg.causal:
            scfg = dataclasses.replace(scfg, causal=cfg.causal)
        return cls(scfg=scfg, D=cfg.d_model, KV=cfg.n_kv_heads,
                   G=cfg.n_heads // cfg.n_kv_heads,
                   Dh=cfg.resolved_head_dim, causal=cfg.causal, mode=mode)

    # ------------------------------------------------------------------
    # quantized predictor state
    # ------------------------------------------------------------------

    @property
    def head_names(self) -> Tuple:
        """Logical sharding axes of the two head dims (scan driver)."""
        return (("heads", None) if self.mode == "flat"
                else ("kv_heads", "qgroups"))

    def _weights2d(self, p: dict) -> Tuple[jax.Array, jax.Array]:
        wq = p["wq"].reshape(self.D, self.KV * self.G * self.Dh)
        wk = p["wk"].reshape(self.D, self.KV * self.Dh)
        return wq, wk

    def _layout(self, qp: jax.Array, kp: jax.Array,
                constrain: bool = False) -> Tuple[jax.Array, jax.Array]:
        """(B, L, H*Dh)/(B, L, KV*Dh) predictions -> head layout
        ``qh (B, KV', G', L, Dh)`` / ``kh (B, KV', L, Dh)``."""
        KV, G, Dh = self.KV, self.G, self.Dh
        B, L = qp.shape[0], qp.shape[1]
        if self.mode == "flat":  # (B, H, 1, L, *) matching attention_forward
            H = KV * G
            qh = qp.reshape(B, L, H, Dh).transpose(0, 2, 1, 3)[:, :, None]
            kh = jnp.repeat(kp.reshape(B, L, KV, Dh).transpose(0, 2, 1, 3),
                            G, axis=1)
            if constrain:
                from repro.sharding.logical import constrain as _cn
                qh = _cn(qh, ("batch", "heads", None, "seq", None))
                kh = _cn(kh, ("batch", "heads", "seq", None))
        else:
            qh = qp.reshape(B, L, KV, G, Dh).transpose(0, 2, 3, 1, 4)
            kh = kp.reshape(B, L, KV, Dh).transpose(0, 2, 1, 3)
            if constrain:
                from repro.sharding.logical import constrain as _cn
                qh = _cn(qh, ("batch", "kv_heads", "qgroups", "seq", None))
        return qh, kh

    def predict_heads(self, p: dict, xn: jax.Array,
                      act_axis: Optional[int] = -1,
                      constrain: bool = False):
        """Run the quantized prediction on the normalized block input and
        return ``(qh, kh)`` in this context's head layout.

        ``act_axis=-1`` (default) is the streaming-reproducible numerics
        (per-token scales); ``act_axis=None`` the offline per-tensor
        variant used by the exact driver.
        """
        from .predict import predict_qk
        wq, wk = self._weights2d(p)
        qp, kp = predict_qk(xn, wq, wk, self.scfg.quant_method,
                            self.scfg.quant_bits, act_axis=act_axis)
        return self._layout(qp, kp, constrain=constrain)

    def encode_pred_qk(self, p: dict, xn: jax.Array):
        """Streaming prediction with the K side emitted as int8 codes.

        xn: (1, C, D) normalized chunk input (structured layout only).
        Returns ``(qh (1, KV, G, C, Dh), k_codes (KV, C, Dh) int8,
        k_scale (C,) float32)`` where
        ``decode_pred_k(k_codes, k_scale)`` is **bit-for-bit** the
        dequantized predicted K that :func:`repro.core.predict.predict_qk`
        would return: the log-domain projection is deterministic on the
        integer codes, so storing codes + per-token scale (the paged
        predictor cache layout, -75% pool bytes at float32 compute dtype)
        loses nothing.
        """
        assert self.mode == "structured", \
            "the paged predictor cache keeps the structured layout"
        scfg = self.scfg
        if scfg.quant_bits > 8:
            raise ValueError(
                f"int8 predictor-cache codes require quant_bits <= 8, got "
                f"{scfg.quant_bits}")
        from .predict import predict_qk_pre
        _, C, _ = xn.shape
        wq, wk = self._weights2d(p)
        q_pred, k_pre = predict_qk_pre(xn, wq, wk, scfg.quant_method,
                                       scfg.quant_bits, act_axis=-1)
        kq, kscale = symmetric_quantize(k_pre, bits=scfg.quant_bits,
                                        axis=-1)       # (1, C, KV*Dh)
        qh, _ = self._layout(q_pred, k_pre)  # kh side recomputed from codes
        k_codes = kq.reshape(C, self.KV, self.Dh).transpose(1, 0, 2) \
            .astype(jnp.int8)
        return qh, k_codes, kscale.reshape(C).astype(jnp.float32)

    def decode_pred_k(self, codes: jax.Array, scale: jax.Array,
                      dtype=None) -> jax.Array:
        """int8 codes (..., S, Dh) + per-token scale (..., S) -> the
        dequantized predicted K heads, bit-for-bit the value the float
        predictor cache used to store.

        ``dtype`` must be the compute dtype the codes were encoded from:
        the projected levels are exact in bf16 and the stored float32
        scale is an exact widening of the compute-dtype scale, so casting
        both *before* the multiply reproduces the compute-dtype product
        exactly (a float32 multiply would differ in the last bf16 ulp and
        flip marginal top-k columns).
        """
        proj = _PROJECTORS[self.scfg.quant_method](
            codes.astype(jnp.float32), self.scfg.quant_bits)
        if dtype is not None:
            proj = proj.astype(dtype)
            scale = scale.astype(dtype)
        return proj * scale[..., None]

    # ------------------------------------------------------------------
    # plan emission
    # ------------------------------------------------------------------

    def plan_block(self, qh_blk: jax.Array, kh: jax.Array, *, k, row0,
                   n_valid_rows, n_cols,
                   col_live: Optional[jax.Array] = None) -> ChunkPlanBlock:
        """One window-aligned plan block -- the unit every driver emits."""
        return plan_chunk(qh_blk, kh, k=k, row0=row0,
                          n_valid_rows=n_valid_rows, n_cols=n_cols,
                          s_threshold=self.scfg.s_threshold,
                          window=self.scfg.window,
                          f_threshold=self.scfg.f_threshold,
                          causal=self.causal, col_live=col_live)

    def vote_block(self, qh_blk: jax.Array, kh: jax.Array, *, k, row0,
                   n_valid_rows, n_cols,
                   col_live: Optional[jax.Array] = None) -> jax.Array:
        """Column-keep contribution only (skips the similarity stage)."""
        return plan_chunk_votes(qh_blk, kh, k=k, row0=row0,
                                n_valid_rows=n_valid_rows, n_cols=n_cols,
                                causal=self.causal, col_live=col_live)

    def row_block_for(self, L: int) -> int:
        return _progressive_row_block(L, self.scfg.window)

    def iter_blocks(self, p: dict, xn: jax.Array,
                    row_block: Optional[int] = None,
                    votes_only: bool = False) -> Iterator:
        """Iterate the progressive planner's row blocks over a full
        sequence -- the single place that owns the predicted-head layout,
        the window-aligned row blocking, and the tail padding.  Both the
        full plan assembly (:meth:`plan_progressive`) and the serving
        vote path (``repro.serving.pager.spls_token_votes``) consume it,
        so the two can never diverge.  Yields
        :class:`~repro.core.spls_chunked.ChunkPlanBlock` per block, or
        just the ``kv_any`` column-keep bools with ``votes_only=True``
        (skipping the similarity stage, whose pairwise tensor is the
        largest intermediate of a full block).
        """
        B, L, _ = xn.shape
        qh, kh = self.predict_heads(p, xn, act_axis=-1)
        w = self.scfg.window
        rb = row_block or self.row_block_for(L)
        assert rb % w == 0, (rb, w)
        nblk = -(-L // rb)
        pad = nblk * rb - L
        if pad:
            qh = jnp.pad(qh, ((0, 0),) * 3 + ((0, pad), (0, 0)))
        k = topk_count(L, self.scfg.k_ratio)
        for i in range(nblk):
            common = dict(k=k, row0=i * rb,
                          n_valid_rows=min(rb, L - i * rb), n_cols=L)
            q_blk = qh[..., i * rb:(i + 1) * rb, :]
            if votes_only:
                yield self.vote_block(q_blk, kh, **common)
            else:
                yield self.plan_block(q_blk, kh, **common)

    def plan_progressive(self, p: dict, xn: jax.Array,
                         row_block: Optional[int] = None) -> SparsityPlan:
        """Full-sequence plan with streaming-reproducible numerics.

        Exactly what a chunk-by-chunk streaming prefill reproduces
        bit-for-bit (per-token quantization; row-local bisection top-k) --
        the serving engines' parity oracle.
        """
        B, L, _ = xn.shape
        blocks = list(self.iter_blocks(p, xn, row_block))
        cat = lambda xs, ax: xs[0] if len(xs) == 1 else jnp.concatenate(xs, ax)
        mask = cat([b.mask for b in blocks], -2)[..., :L, :]
        q_crit = cat([b.q_critical for b in blocks], -1)[..., :L]
        q_lead = cat([b.q_leader for b in blocks], -1)[..., :L]
        kv_keep = blocks[0].kv_any
        for b in blocks[1:]:
            kv_keep = kv_keep | b.kv_any
        if self.scfg.ffn_sparsity:
            ffn_crit = cat([b.ffn_critical for b in blocks], -1)[..., :L]
            ffn_lead = cat([b.ffn_leader for b in blocks], -1)[..., :L]
        else:
            ar = jnp.arange(L, dtype=jnp.int32)
            ffn_crit = jnp.ones((B, L), bool)
            ffn_lead = jnp.broadcast_to(ar, (B, L))
        # attn_mask == mask & kv_keep[..., None, :] identically: any column
        # a row's mask selects is by definition kept in that head, so the
        # intersection is a no-op (this is also what makes simulation-mode
        # execution reproducible row-locally by a streaming prefill).
        return SparsityPlan(attn_mask=mask, q_critical=q_crit,
                            q_leader=q_lead, kv_keep=kv_keep,
                            ffn_critical=ffn_crit, ffn_leader=ffn_lead)

    def plan_scan(self, p: dict, xn: jax.Array,
                  row_block: Optional[int] = None) -> ChunkedPlan:
        """Long-sequence driver: ``lax.scan`` over the shared plan-block
        primitive; O(row_block * L) peak, plan-lite output (no O(L^2)
        mask)."""
        B, L, _ = xn.shape
        qh, kh = self.predict_heads(p, xn, act_axis=None, constrain=True)
        rb = row_block or self.row_block_for(L)
        return chunked_plan_scan(
            qh, kh, k_ratio=self.scfg.k_ratio,
            s_threshold=self.scfg.s_threshold, window=self.scfg.window,
            f_threshold=self.scfg.f_threshold, row_block=rb,
            causal=self.scfg.causal, head_names=self.head_names)

    def plan_exact(self, p: dict, xn: jax.Array) -> SparsityPlan:
        """Offline exact driver: full PAM, exact top-k, per-tensor
        quantization -- the accuracy-study numerics (the paper's Fig. 5a
        as one shot).  Not streaming-reproducible; training/simulation
        only."""
        from repro.core import mfi as _mfi
        from repro.core import similarity as _sim
        from repro.core import topk as _topk
        from repro.sharding.logical import constrain as _cn

        scfg = self.scfg
        B, L, _ = xn.shape
        qh, kh = self.predict_heads(p, xn, act_axis=None, constrain=True)
        pam = jnp.einsum("bkgqd,bkld->bkgql", qh, kh) * (self.Dh ** -0.5)
        if scfg.causal:
            neg = jnp.asarray(jnp.finfo(pam.dtype).min / 2, pam.dtype)
            tri = jnp.tril(jnp.ones((L, L), dtype=bool))
            pam = jnp.where(tri, pam, neg)

        spa, mask = _topk.sparsify_pam(pam, scfg.k_ratio)
        if scfg.causal:
            tri = jnp.tril(jnp.ones((L, L), bool))
            mask = mask & tri
            spa = jnp.where(mask, spa, jnp.zeros_like(spa))
        sim = _sim.local_similarity(spa, scfg.window, scfg.s_threshold)
        kv_keep = _topk.kv_keep_from_mask(mask)
        if scfg.ffn_sparsity:
            # MFI votes across all H = KV*G heads
            leaders_h = sim.leader.reshape(B, self.KV * self.G, L)
            ffn = _mfi.mfi_ffn_sparsity(leaders_h, scfg.window,
                                        scfg.f_threshold)
            ffn_crit, ffn_leader = ffn.is_critical, ffn.leader
        else:
            ar = jnp.arange(L, dtype=jnp.int32)
            ffn_crit = jnp.ones((B, L), bool)
            ffn_leader = jnp.broadcast_to(ar, (B, L))
        return SparsityPlan(attn_mask=mask & kv_keep[..., None, :],
                            q_critical=sim.is_critical, q_leader=sim.leader,
                            kv_keep=kv_keep, ffn_critical=ffn_crit,
                            ffn_leader=ffn_leader)


# ---------------------------------------------------------------------------
# horizon-finalized column votes
# ---------------------------------------------------------------------------

def own_column_keep(kv_any: jax.Array, *, start, chunk: int, valid,
                    last_keep, vote_need: int = 1) -> jax.Array:
    """Keep decision for the *current* chunk's own columns (jit-side).

    kv_any: (B, KV', G', S) this chunk's plan-block column votes; start /
    valid: the chunk's slot window; last_keep: the prompt's final position
    (always kept -- it anchors the decode continuation, mirroring
    ``keep_from_votes``).  Returns (chunk,) bool: a new column survives
    iff at least ``vote_need`` heads' rows selected it -- the same
    cross-head agreement threshold the end-of-prefill prune vote applies
    (``ceil(spls_prune_vote * H)``), evaluated on the chunk's own plan
    block.  This is the ``vote_horizon == 1`` finalization, and it lands
    *before* formal K/V generation (prediction precedes QKV, the paper's
    Fig. 5a ordering) -- which is what lets the K/V projection skip the
    pruned columns entirely.
    """
    # pad so the dynamic slice can never clamp-shift near the table tail
    padded = jnp.pad(kv_any, [(0, 0)] * (kv_any.ndim - 1) + [(0, chunk)])
    own = jax.lax.dynamic_slice_in_dim(padded, start, chunk, axis=-1)
    idx = jnp.arange(chunk, dtype=jnp.int32)
    hv = own.astype(jnp.int32).sum(axis=tuple(range(own.ndim - 1)))
    keep = (hv >= vote_need) & (idx < valid)
    return keep | (start + idx == last_keep)


def pack_within_capacity(keep: jax.Array, capacity: int,
                         anchor: Optional[jax.Array] = None) -> jax.Array:
    """(C,) keep mask -> the subset that fits the static capacity in the
    stable pack order (:func:`repro.core.sparse_exec.pack_by_mask`): the
    n-th kept row occupies slot n-1.  Overflow columns are dropped from
    the keep set entirely (never materialized, never attendable) -- the
    capacity controller observes the overflow and escalates its bucket.

    ``anchor`` (C,) marks the forced decode anchor (the prompt's final
    position): when present-and-kept it is **reserved a slot** regardless
    of its index position -- it is the highest index of the final chunk,
    so plain pack order would drop it first on overflow, and a dropped
    anchor is catastrophic (decode would run without the last prompt
    token's K/V) where any other overflow merely degrades.  Non-anchor
    columns are capped to ``capacity - 1`` in that case.
    """
    if anchor is None:
        return keep & (jnp.cumsum(keep) - 1 < capacity)
    anchor = anchor & keep
    present = anchor.any().astype(jnp.int32)
    others = keep & ~anchor
    capped = others & (jnp.cumsum(others) - 1 < capacity - present)
    return capped | anchor


def horizon_update_live(live: np.ndarray, head_votes: np.ndarray, *,
                        start: int, valid: int, chunk: int, horizon: int,
                        last_keep: int, vote_need: int = 1,
                        kv_capacity: Optional[int] = None,
                        metrics=None) -> np.ndarray:
    """Host-side liveness update after one streamed chunk's votes landed.

    live: (S,) current live mask; head_votes: (S,) accumulated cross-head
    keep-vote *counts* (layer 0, summed over heads).  A column that has
    been votable for ``horizon`` consecutive chunks (its arrival chunk
    included) while still below the cross-head agreement threshold
    (``vote_need = ceil(spls_prune_vote * H)`` heads -- the same
    criterion the end-of-prefill vote applies) is finalized as pruned;
    once a column wins the threshold it can never be finalized (votes
    are monotone, so the keep bit is sticky).  With ``kv_capacity``
    given (the ``horizon == 1`` packed-K/V path), the current chunk's
    own columns are additionally capped to the packed projection
    capacity in pack order -- mirroring exactly what
    :func:`own_column_keep` + :func:`pack_within_capacity` materialized
    on device, so host bookkeeping and device state cannot disagree.
    The prompt's final position (``last_keep``) is never finalized.

    ``metrics`` (optional) is a duck-typed
    :class:`~repro.observability.metrics.MetricsRegistry`: this function
    is the only place that knows whether a column died to the vote
    horizon or to the kv-capacity pack, so it owns the
    ``spls/horizon_finalized_cols`` / ``spls/horizon_kv_capacity_drops``
    counters.
    """
    live = np.asarray(live).copy()
    head_votes = np.asarray(head_votes)
    S = live.shape[0]
    sl = np.arange(S)
    kept_by_vote = head_votes >= vote_need
    if kv_capacity is not None and horizon == 1:
        own = slice(start, min(start + chunk, S))
        sl_own = sl[own]
        anchor = sl_own == last_keep
        keep_own = (kept_by_vote[own] | anchor) & (sl_own - start < valid)
        anchor = anchor & keep_own
        others = keep_own & ~anchor
        written = (others & (np.cumsum(others) - 1
                             < kv_capacity - int(anchor.any()))) | anchor
        if metrics is not None:
            newly_dead = live[own] & ~written
            n_vote = int((newly_dead & ~keep_own).sum())
            n_pack = int((newly_dead & keep_own).sum())
            if n_vote:
                metrics.counter("spls/horizon_finalized_cols").inc(n_vote)
            if n_pack:
                metrics.counter(
                    "spls/horizon_kv_capacity_drops").inc(n_pack)
        live[own] &= written
        return live
    cur = start // chunk
    elapsed = cur - sl // chunk + 1
    dead = (live & ~kept_by_vote & (sl < start + valid)
            & (elapsed >= horizon) & (sl != last_keep))
    if metrics is not None:
        n_dead = int(dead.sum())
        if n_dead:
            metrics.counter("spls/horizon_finalized_cols").inc(n_dead)
    live[dead] = False
    return live


# ---------------------------------------------------------------------------
# compat drivers (the signatures models.blocks re-exports)
# ---------------------------------------------------------------------------

def build_block_plan(cfg, p: dict, xn: jax.Array) -> Optional[SparsityPlan]:
    """Exact-top-k SPLS plan from the normalized block input (before QKV
    generation; TP-friendly (B, KV, G, ...) layout).  ``p`` is the block
    param dict (``p["attn"]`` holds the projection weights)."""
    if not cfg.spls.enabled:
        return None
    return PlanContext.for_config(cfg).plan_exact(p["attn"], xn)


def build_block_plan_chunked(cfg, p: dict, xn: jax.Array) -> ChunkedPlan:
    """Progressive-generation plan for long sequences (O(row_block * L));
    the ``lax.scan`` driver of the unified planner."""
    ctx = PlanContext.for_config(cfg)
    L = xn.shape[1]
    return ctx.plan_scan(p["attn"], xn,
                         row_block=max(ctx.scfg.window, min(512, L)))


def build_block_plan_progressive(cfg, p: dict, xn: jax.Array,
                                 row_block: Optional[int] = None
                                 ) -> Optional[SparsityPlan]:
    """Serving-mode SPLS plan: the numerics a *streaming* predictor can
    reproduce exactly, assembled over the full sequence.  Returns ``None``
    when SPLS is disabled."""
    if not cfg.spls.enabled:
        return None
    return PlanContext.for_config(cfg).plan_progressive(p["attn"], xn,
                                                        row_block)


def progressive_plan_blocks(cfg, p: dict, xn: jax.Array,
                            row_block: Optional[int] = None,
                            votes_only: bool = False) -> Iterator:
    """Iterate the progressive planner's row blocks for a full sequence
    (see :meth:`PlanContext.iter_blocks`)."""
    return PlanContext.for_config(cfg).iter_blocks(
        p["attn"], xn, row_block=row_block, votes_only=votes_only)
