"""Execution of the formal computation phase under a SparsityPlan.

Two execution modes, both bit-identical in what they *mean* but differing in
how the saved work is realised:

* **simulation** -- dense tensor math with gather/mask semantics.  The
  numerics are exactly the accelerator's (similar rows reuse their leader's
  attention/FFN output; pruned K/V columns are masked out), and the FLOPs
  accountant (:mod:`repro.core.flops`) reports the work the accelerator
  would skip.  This is the mode used for accuracy studies and training.

* **capacity** -- the TPU-native adaptation.  Dynamic row counts are
  incompatible with XLA's static shapes, so critical rows/tokens are packed
  into fixed-capacity buffers (like MoE capacity routing), computed densely
  at the reduced size, and scattered back through the leader map.  With
  ``capacity == L`` this is exactly equivalent to simulation mode (tests
  assert this); with capacity < L the compute actually shrinks and overflow
  rows fall back to their window leader.

Hardware-adaptation note: the ASIC exploits *perfectly* dynamic sparsity via
its dynamic-allocation FIFO scheduler (Sec. IV-D).  The TPU analogue of that
scheduler is exactly the pack-to-capacity + static-matmul strategy here:
load balance comes from the pack, and "FIFO recovery" becomes a gather.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .spls import SparsityPlan

__all__ = [
    "gather_rows",
    "pack_by_mask",
    "unpack_by_leader",
    "Compaction",
    "compact_rows",
    "spls_attention",
    "spls_attention_packed",
    "spls_attention_chunked",
    "spls_ffn",
    "spls_ffn_packed",
]

_NEG = -1e30


def gather_rows(x: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather along the row axis (-2) with a (..., L) index map."""
    return jnp.take_along_axis(x, idx[..., None], axis=-2)


def _pack_order(mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Stable critical-first pack order of ``mask`` (..., L).

    Returns ``(order, order_pos)``: ``order`` lists source rows packed
    first (True rows in index order, then False rows in index order);
    ``order_pos[row]`` is the unclamped slot each row would occupy.  The
    single source of the pack ordering -- :func:`pack_by_mask` and
    :func:`compact_rows` both build on it, which is what keeps their
    full-capacity numerics interchangeable (parity-test-pinned).
    """
    order = jnp.argsort(~mask, axis=-1, stable=True).astype(jnp.int32)
    order_pos = jnp.argsort(order, axis=-1, stable=True).astype(jnp.int32)
    return order, order_pos


def pack_by_mask(mask: jax.Array, capacity: int) -> Tuple[jax.Array, jax.Array]:
    """Pack True positions of ``mask`` (..., L) first, truncated to capacity.

    Returns ``(perm, slot_of)``:
      perm:    (..., C) int32 -- source row index for each packed slot (stable
               order; slots past the true count hold trailing non-critical
               rows, which are computed wastefully but harmlessly).
      slot_of: (..., L) int32 -- packed slot that holds each source row's
               result, clamped into [0, C).  Rows that did not fit map to
               slot of their nearest packed predecessor (capacity overflow
               fallback).
    """
    L = mask.shape[-1]
    C = min(capacity, L)
    order, order_pos = _pack_order(mask)
    perm = order[..., :C]
    slot_of = jnp.minimum(order_pos, jnp.int32(C - 1))
    return perm, slot_of


def unpack_by_leader(packed: jax.Array, slot_of: jax.Array,
                     leader: jax.Array) -> jax.Array:
    """Scatter packed rows back to full length through the leader map.

    ``out[row] = packed[slot_of[leader[row]]]`` -- similar rows read their
    leader's slot; critical rows read their own.
    """
    src_slot = jnp.take_along_axis(slot_of, leader, axis=-1)
    return gather_rows(packed, src_slot)


class Compaction(NamedTuple):
    """Static-capacity packing of critical rows, ready for packed execution.

    The plan->compaction adapter consumed by the packed compute backends
    (:mod:`repro.sparse_compute`): ``perm`` names the source row each packed
    slot computes, ``src_slot`` the packed slot each *output* row reads --
    leader indirection already resolved, capacity overflow redirected to the
    window leader (see :func:`compact_rows`).
    """

    perm: jax.Array        # (..., C) int32 source row per packed slot
    src_slot: jax.Array    # (..., *extra, L) int32 slot each row reads
    n_critical: jax.Array  # (...,) int32 critical-row count (capacity
    #                        controller observation; excludes nothing)


def _window_leader(crit: jax.Array, window: int) -> jax.Array:
    """(..., L) index of the first critical row in each row's window
    (``L`` where a window has none -- callers must guard)."""
    L = crit.shape[-1]
    ids = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), crit.shape)
    cand = jnp.where(crit, ids, jnp.int32(L))
    pad = (-L) % window
    if pad:
        cand = jnp.pad(cand, [(0, 0)] * (cand.ndim - 1) + [(0, pad)],
                       constant_values=L)
    nw = cand.shape[-1] // window
    wmin = cand.reshape(*cand.shape[:-1], nw, window).min(-1)   # (..., nw)
    return jnp.take_along_axis(wmin, ids // window, axis=-1)


def compact_rows(crit: jax.Array, capacity: int,
                 leader: Optional[jax.Array] = None,
                 window: Optional[int] = None) -> Compaction:
    """Turn a critical-row mask (+ leader map) into a :class:`Compaction`.

    crit: (..., L) bool; leader: (..., *extra, L) int32 row each output row
    recovers from (extra leading axes -- e.g. per-head leaders over a
    cross-head union pack -- broadcast against ``crit``'s dims); ``None``
    means every row reads itself.  Rows pack in stable index order,
    critical first (:func:`pack_by_mask`'s order).

    Capacity overflow: a row whose leader did not fit falls back to its
    **window leader** -- the first critical row of the leader's similarity
    window (leaders are window-local, so that is the row's own window) --
    when ``window`` is given and that row is packed; the last packed slot
    is the final fallback (the legacy clamp).  ``window=None`` keeps the
    legacy clamp-only behavior.
    """
    L = crit.shape[-1]
    C = min(capacity, L)
    order, order_pos = _pack_order(crit)
    perm = order[..., :C]
    target = leader if leader is not None else jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32), crit.shape)
    extra = target.ndim - crit.ndim
    op = order_pos.reshape(order_pos.shape[:-1] + (1,) * extra + (L,))
    op = jnp.broadcast_to(op, target.shape[:-1] + (L,))
    if window is not None:
        wl = _window_leader(crit, window)                       # (..., L)
        wl = jnp.broadcast_to(
            wl.reshape(wl.shape[:-1] + (1,) * extra + (L,)), op.shape)
        wlt = jnp.take_along_axis(wl, target, axis=-1)
        wls = jnp.minimum(wlt, jnp.int32(L - 1))
        overflow = jnp.take_along_axis(op, target, axis=-1) >= C
        fb_ok = (wlt < L) & (jnp.take_along_axis(op, wls, axis=-1) < C)
        target = jnp.where(overflow & fb_ok, wls, target)
    src_slot = jnp.minimum(jnp.take_along_axis(op, target, axis=-1),
                           jnp.int32(C - 1))
    return Compaction(perm=perm, src_slot=src_slot,
                      n_critical=crit.sum(-1).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _masked_softmax(scores: jax.Array, mask: jax.Array) -> jax.Array:
    scores = jnp.where(mask, scores, _NEG)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m) * mask.astype(scores.dtype)
    return e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-9)


def spls_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   plan: SparsityPlan, scale: Optional[float] = None,
                   softcap: Optional[float] = None) -> jax.Array:
    """Simulation-mode sparse attention.  q,k,v: (B, H, L, Dh).

    Semantics: a similar row's output is its leader's output (so both the Q
    vector and the SPA mask row are the leader's); pruned K/V columns never
    receive probability mass.
    """
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    q_eff = gather_rows(q, plan.q_leader)
    mask_eff = jnp.take_along_axis(plan.attn_mask, plan.q_leader[..., None],
                                   axis=-2)
    s = jnp.einsum("...qd,...kd->...qk", q_eff, k) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    a = _masked_softmax(s, mask_eff)
    return jnp.einsum("...qk,...kd->...qd", a, v)


def spls_attention_packed(q: jax.Array, k: jax.Array, v: jax.Array,
                          plan: SparsityPlan, q_capacity: int,
                          kv_capacity: int, scale: Optional[float] = None,
                          softcap: Optional[float] = None) -> jax.Array:
    """Capacity-mode sparse attention with real compute reduction.

    Packs critical Q rows to ``q_capacity`` and surviving K/V positions to
    ``kv_capacity`` per (batch, head); computes a (C_q x C_kv) attention and
    scatters rows back through the leader map.
    """
    L, Dh = q.shape[-2], q.shape[-1]
    scale = scale if scale is not None else Dh ** -0.5
    q_perm, q_slot = pack_by_mask(plan.q_critical, q_capacity)
    kv_perm, _ = pack_by_mask(plan.kv_keep, kv_capacity)

    qp = gather_rows(q, q_perm)                       # (B,H,Cq,Dh)
    kp = gather_rows(k, kv_perm)                      # (B,H,Ck,Dh)
    vp = gather_rows(v, kv_perm)
    # packed mask: rows by q_perm, cols by kv_perm
    mrows = jnp.take_along_axis(plan.attn_mask, q_perm[..., None], axis=-2)
    mp = jnp.take_along_axis(mrows, kv_perm[..., None, :], axis=-1)
    # slots past the kv keep-count must stay dead even if mask bits are set
    kv_alive = jnp.take_along_axis(plan.kv_keep, kv_perm, axis=-1)
    mp = mp & kv_alive[..., None, :]

    s = jnp.einsum("...qd,...kd->...qk", qp, kp) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    a = _masked_softmax(s, mp)
    op = jnp.einsum("...qk,...kd->...qd", a, vp)         # (B,H,Cq,Dh)
    return unpack_by_leader(op, q_slot, plan.q_leader)


def spls_attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                           plan, q_capacity: int, kv_capacity: int,
                           scale: Optional[float] = None,
                           softcap: Optional[float] = None,
                           kv_chunk: int = 2048,
                           causal: bool = True,
                           window: Optional[int] = None) -> jax.Array:
    """Long-sequence capacity-mode sparse attention (ChunkedPlan).

    q: (B, KV', G', L, Dh); k/v: (B, KV', L, Dh) (un-repeated).  Packs
    critical Q rows and surviving KV columns to static capacities, then
    runs an online-softmax scan over packed-KV chunks with an *index-based*
    causal mask (packed positions carry their original row/col ids).  Peak
    memory O(Cq * kv_chunk) per head; compute O(Cq * Ckv) -- the real
    FLOP reduction of the paper's inter-row + column sparsity at 32k+.
    """
    B, KVp, Gp, L, Dh = q.shape
    scale = scale if scale is not None else Dh ** -0.5
    Cq, Ck = min(q_capacity, L), min(kv_capacity, L)
    kv_chunk = min(kv_chunk, Ck)

    q_perm, q_slot = pack_by_mask(plan.q_critical, Cq)
    kv_perm, _ = pack_by_mask(plan.kv_keep, Ck)

    qp = gather_rows(q, q_perm)                                 # (B,K,G,Cq,D)
    kr = jnp.broadcast_to(k[:, :, None], (B, KVp, Gp, L, Dh))
    vr = jnp.broadcast_to(v[:, :, None], (B, KVp, Gp, L, Dh))
    kp = gather_rows(kr, kv_perm)                               # (B,K,G,Ck,D)
    vp = gather_rows(vr, kv_perm)
    kv_alive = jnp.take_along_axis(plan.kv_keep, kv_perm, axis=-1)

    pad = (-Ck) % kv_chunk
    if pad:  # ragged capacity: dead padded columns keep the chunk grid even
        kp = jnp.pad(kp, ((0, 0),) * 3 + ((0, pad), (0, 0)))
        vp = jnp.pad(vp, ((0, 0),) * 3 + ((0, pad), (0, 0)))
        kv_perm = jnp.pad(kv_perm, ((0, 0),) * 3 + ((0, pad),))
        kv_alive = jnp.pad(kv_alive, ((0, 0),) * 3 + ((0, pad),))
        Ck += pad

    nC = Ck // kv_chunk
    kc = kp.reshape(B, KVp, Gp, nC, kv_chunk, Dh).transpose(3, 0, 1, 2, 4, 5)
    vc = vp.reshape(B, KVp, Gp, nC, kv_chunk, Dh).transpose(3, 0, 1, 2, 4, 5)
    idc = kv_perm.reshape(B, KVp, Gp, nC, kv_chunk).transpose(3, 0, 1, 2, 4)
    alc = kv_alive.reshape(B, KVp, Gp, nC, kv_chunk).transpose(3, 0, 1, 2, 4)

    def body(carry, ck):
        m_run, l_run, acc = carry
        k_c, v_c, id_c, al_c = ck
        s = jnp.einsum("bkgqd,bkgld->bkgql", qp, k_c).astype(jnp.float32)
        s = s * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        mask = al_c[..., None, :]
        if causal:
            mask = mask & (id_c[..., None, :] <= q_perm[..., :, None])
        if window is not None:
            # packed positions carry original ids, so the sliding window is
            # an index-based band (symmetric when not causal)
            mask = mask & (q_perm[..., :, None] - id_c[..., None, :] < window)
            if not causal:
                mask = mask & (id_c[..., None, :] - q_perm[..., :, None]
                               < window)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m_run, s.max(-1))
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None]) * mask.astype(jnp.float32)
        l_new = l_run * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgql,bkgld->bkgqd", p.astype(v_c.dtype), v_c
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    init = (jnp.full((B, KVp, Gp, Cq), -1e30, jnp.float32),
            jnp.zeros((B, KVp, Gp, Cq), jnp.float32),
            jnp.zeros((B, KVp, Gp, Cq, Dh), jnp.float32))
    (m_f, l_f, acc), _ = jax.lax.scan(body, init, (kc, vc, idc, alc))
    op = (acc / jnp.maximum(l_f, 1e-9)[..., None]).astype(q.dtype)
    return unpack_by_leader(op, q_slot, plan.q_leader)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def spls_ffn(x: jax.Array, ffn_fn: Callable[[jax.Array], jax.Array],
             plan: SparsityPlan) -> jax.Array:
    """Simulation-mode sparse FFN: compute dense, recover similar tokens from
    their MFI leader (x: (B, L, D))."""
    y = ffn_fn(x)
    return gather_rows(y, plan.ffn_leader)


def spls_ffn_packed(x: jax.Array, ffn_fn: Callable[[jax.Array], jax.Array],
                    plan: SparsityPlan, capacity: int,
                    window: Optional[int] = None) -> jax.Array:
    """Capacity-mode sparse FFN: pack critical tokens, compute, scatter.

    With ``window`` (the SPLS similarity window) given, capacity-overflow
    rows fall back to their *window leader's* output exactly (the first
    packed critical row of their window) instead of the legacy last-slot
    clamp; see :func:`compact_rows`.
    """
    comp = compact_rows(plan.ffn_critical, capacity, leader=plan.ffn_leader,
                        window=window)
    xp = gather_rows(x, comp.perm)
    yp = ffn_fn(xp)
    return gather_rows(yp, comp.src_slot)
