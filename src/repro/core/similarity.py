"""Local (fixed-window) similarity on the SPA -> critical / similar rows.

Sec. III-B: the L x L SPA is partitioned into non-overlapping row windows of
width ``w`` (the paper uses w=8).  Within each window, rows are compared with
the L1 distance; rows whose normalized distance to an earlier *critical* row
falls below the similarity threshold ``s`` become *similar* rows, pointing at
that critical row (their "leader").  This costs ``L^2 (w-1)`` add/sub total
instead of the quadratic-in-L cost of global similarity -- the core insight
of the paper.

Windows are independent, so the whole computation is embarrassingly parallel
across (batch, head, window); the greedy leader scan is over the *static*
window width only and is unrolled at trace time.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["LocalSimilarity", "windowed_l1", "local_similarity", "num_windows"]


class LocalSimilarity(NamedTuple):
    """Similarity structure for one SPA.

    Attributes (leading dims ``(..., H)`` broadcast over batch/heads):
      is_critical: (..., H, L) bool -- row must actually be computed.
      leader:      (..., H, L) int32 -- global row index whose attention row
                   this row reuses; ``leader[i] == i`` iff critical.
      dist:        (..., H, nw, w, w) float32 normalized pairwise distances
                   (diagnostic; zero on the diagonal).
    """

    is_critical: jax.Array
    leader: jax.Array
    dist: jax.Array


def num_windows(L: int, w: int) -> int:
    return math.ceil(L / w)


def _pad_rows(x: jax.Array, L_pad: int) -> jax.Array:
    pad = L_pad - x.shape[-2]
    if pad == 0:
        return x
    cfg = [(0, 0)] * (x.ndim - 2) + [(0, pad), (0, 0)]
    return jnp.pad(x, cfg)


def windowed_l1(spa: jax.Array, w: int, eps: float = 1e-6) -> jax.Array:
    """Normalized pairwise L1 distances within each row window.

    Input (..., L, Lk); output (..., nw, w, w) with
    ``d[i,j] = ||a_i - a_j||_1 / (||a_i||_1 + ||a_j||_1 + eps)`` in [0, 1].
    Rows are compared on their SPA values (zeros where top-k dropped), which
    is exactly what the hardware similarity unit sees.
    """
    *lead, L, Lk = spa.shape
    nw = num_windows(L, w)
    xp = _pad_rows(spa, nw * w).reshape(*lead, nw, w, Lk)
    diff = jnp.abs(xp[..., :, None, :] - xp[..., None, :, :]).sum(-1)
    norm = jnp.abs(xp).sum(-1)
    denom = norm[..., :, None] + norm[..., None, :] + eps
    return (diff / denom).astype(jnp.float32)


def local_similarity(spa: jax.Array, w: int, s: float,
                     valid_len: Optional[int] = None) -> LocalSimilarity:
    """Greedy leader clustering within fixed windows.

    Row 0 of each window is critical.  Each subsequent row joins the *first*
    earlier critical row within its window whose normalized L1 distance is
    <= ``s``; otherwise it becomes critical itself.  ``s`` larger -> more
    rows classified similar -> more sparsity (matches Fig. 16).

    ``valid_len`` masks padded tail rows (they are reported non-critical with
    ``leader = row_index`` and never serve as leaders).
    """
    *lead, L, _ = spa.shape
    if valid_len is None:
        valid_len = L
    nw = num_windows(L, w)
    d = windowed_l1(spa, w)  # (..., nw, w, w)
    row_ids = jnp.arange(nw * w, dtype=jnp.int32).reshape(nw, w)
    valid = (row_ids < valid_len)  # (nw, w)
    valid = jnp.broadcast_to(valid, (*lead, nw, w))

    is_crit = [None] * w
    leader_off = [None] * w  # local offset within window
    is_crit[0] = valid[..., 0]
    leader_off[0] = jnp.zeros(valid.shape[:-1], dtype=jnp.int32)
    for j in range(1, w):
        # eligibility of each earlier row i < j as a leader for row j
        elig = jnp.stack(
            [is_crit[i] & (d[..., i, j] <= s) for i in range(j)], axis=-1)
        found = jnp.any(elig, axis=-1)
        first = jnp.argmax(elig, axis=-1).astype(jnp.int32)  # first True
        vj = valid[..., j]
        is_crit[j] = vj & ~found
        leader_off[j] = jnp.where(vj & found, first, jnp.int32(j))

    crit = jnp.stack(is_crit, axis=-1)                       # (..., nw, w)
    loff = jnp.stack(leader_off, axis=-1).astype(jnp.int32)  # (..., nw, w)
    base = (jnp.arange(nw, dtype=jnp.int32) * w)[:, None]
    leader_global = (loff + base).reshape(*lead, nw * w)[..., :L]
    crit = crit.reshape(*lead, nw * w)[..., :L]
    # clamp leaders of (possibly padded) rows into range
    leader_global = jnp.minimum(leader_global, jnp.int32(L - 1))
    return LocalSimilarity(is_critical=crit, leader=leader_global, dist=d)
