"""SPLS: Sparsity Prediction with Local Similarity -- the paper's mechanism.

Pipeline (Fig. 5a):
  1. HLog-quantized attention prediction  -> PAM        (predict.py)
  2. row-wise top-k pruning               -> SPA + mask (topk.py)
  3. fixed-window local similarity        -> critical/similar Q rows
  4. zero-column detection                -> K/V keep mask
  5. MFI vote across heads                -> FFN token sparsity

The output is a :class:`SparsityPlan` consumed by the execution layer
(``sparse_exec.py``) and by the FLOPs accountant (``flops.py``).  Everything
is functional and jit-safe: all shapes depend only on static config.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .mfi import FFNSparsity, mfi_ffn_sparsity
from .predict import predicted_attention
from .similarity import LocalSimilarity, local_similarity
from .topk import kv_keep_from_mask, sparsify_pam

__all__ = ["SPLSConfig", "SparsityPlan", "build_plan", "plan_stats"]


@dataclasses.dataclass(frozen=True)
class SPLSConfig:
    """Hyper-parameters of the SPLS mechanism (Sec. V-B methodology).

    ``k_ratio`` smaller -> more attention sparsity; ``s_threshold`` larger ->
    more QKV sparsity; ``f_threshold`` smaller -> more FFN sparsity.
    """

    enabled: bool = True
    k_ratio: float = 0.12          # row-wise top-k ratio (paper MRPC setting)
    s_threshold: float = 0.6       # local-similarity threshold s
    f_threshold: int = 6           # MFI vote threshold f (heads >= f agree)
    window: int = 8                # fixed local window width w
    quant_method: str = "hlog"     # hlog | hlog_bitlevel | pot | apot | none
    quant_bits: int = 8
    causal: bool = True
    ffn_sparsity: bool = True      # allow disabling FFN stage (Fig. 16 runs)
    qkv_sparsity: bool = True
    # Capacity-mode execution (TPU-native static shapes); ratios of L.
    q_capacity_ratio: float = 1.0
    kv_capacity_ratio: float = 1.0


class SparsityPlan(NamedTuple):
    """Everything the formal computation phase needs.  B=batch, H=heads.

    attn_mask:  (B, H, L, L) bool   intra-row SPA mask (and causal).
    q_critical: (B, H, L)    bool   rows whose Q / attention row is computed.
    q_leader:   (B, H, L)    int32  attention-row recovery map.
    kv_keep:    (B, H, L)    bool   key/value positions that survive.
    ffn_critical: (B, L)     bool   tokens whose FFN is computed.
    ffn_leader: (B, L)       int32  FFN output recovery map.
    """

    attn_mask: jax.Array
    q_critical: jax.Array
    q_leader: jax.Array
    kv_keep: jax.Array
    ffn_critical: jax.Array
    ffn_leader: jax.Array


def _dense_plan(B: int, H: int, L: int, causal: bool) -> SparsityPlan:
    tri = jnp.tril(jnp.ones((L, L), bool)) if causal else jnp.ones((L, L), bool)
    ar = jnp.arange(L, dtype=jnp.int32)
    return SparsityPlan(
        attn_mask=jnp.broadcast_to(tri, (B, H, L, L)),
        q_critical=jnp.ones((B, H, L), bool),
        q_leader=jnp.broadcast_to(ar, (B, H, L)),
        kv_keep=jnp.ones((B, H, L), bool),
        ffn_critical=jnp.ones((B, L), bool),
        ffn_leader=jnp.broadcast_to(ar, (B, L)),
    )


def build_plan(x: jax.Array, wq: jax.Array, wk: jax.Array, n_heads: int,
               cfg: SPLSConfig, valid_len: Optional[int] = None) -> SparsityPlan:
    """Run the full SPLS prediction pipeline on activations ``x`` (B, L, D)."""
    B, L, _ = x.shape
    if not cfg.enabled:
        return _dense_plan(B, n_heads, L, cfg.causal)

    pam = predicted_attention(x, wq, wk, n_heads, cfg.quant_method,
                              cfg.quant_bits, causal=cfg.causal)
    spa, mask = sparsify_pam(pam, cfg.k_ratio)
    if cfg.causal:
        # early rows have fewer valid positions than k; top-k may have been
        # forced onto masked entries -- clear them.
        tri = jnp.tril(jnp.ones((L, L), bool))
        mask = mask & tri
        spa = jnp.where(mask, spa, jnp.zeros_like(spa))

    if cfg.qkv_sparsity:
        sim: LocalSimilarity = local_similarity(
            spa, cfg.window, cfg.s_threshold, valid_len=valid_len)
        q_critical, q_leader = sim.is_critical, sim.leader
        kv_keep = kv_keep_from_mask(mask)
    else:
        ar = jnp.arange(L, dtype=jnp.int32)
        q_critical = jnp.ones((B, n_heads, L), bool)
        q_leader = jnp.broadcast_to(ar, (B, n_heads, L))
        kv_keep = jnp.ones((B, n_heads, L), bool)

    if cfg.ffn_sparsity and cfg.qkv_sparsity:
        ffn: FFNSparsity = mfi_ffn_sparsity(q_leader, cfg.window, cfg.f_threshold)
        ffn_critical, ffn_leader = ffn.is_critical, ffn.leader
    else:
        ar = jnp.arange(L, dtype=jnp.int32)
        ffn_critical = jnp.ones((B, L), bool)
        ffn_leader = jnp.broadcast_to(ar, (B, L))

    # The effective attention row of a similar row is its leader's row; the
    # leader's mask already encodes intra-row sparsity.  Recovered rows also
    # must not attend to pruned K/V columns.
    attn_mask = mask & kv_keep[..., None, :]
    return SparsityPlan(attn_mask=attn_mask, q_critical=q_critical,
                        q_leader=q_leader, kv_keep=kv_keep,
                        ffn_critical=ffn_critical, ffn_leader=ffn_leader)


def plan_stats(plan: SparsityPlan) -> dict:
    """Sparsity ratios (fraction *removed*) per component, as scalars."""
    q_sparsity = 1.0 - jnp.mean(plan.q_critical.astype(jnp.float32))
    kv_sparsity = 1.0 - jnp.mean(plan.kv_keep.astype(jnp.float32))
    attn_keep = jnp.mean(plan.attn_mask.astype(jnp.float32))
    # attention rows actually computed
    row_keep = jnp.mean(plan.q_critical.astype(jnp.float32))
    ffn_sparsity = 1.0 - jnp.mean(plan.ffn_critical.astype(jnp.float32))
    return {
        "q_sparsity": q_sparsity,
        "kv_sparsity": kv_sparsity,
        "attn_mask_keep": attn_keep,
        "attn_effective_keep": attn_keep * row_keep,
        "ffn_sparsity": ffn_sparsity,
    }
