"""ESACT core: the SPLS mechanism (Sparsity Prediction with Local Similarity).

Public API:
  quantizers      -- HLog / PoT / APoT log-domain quantizers + bit-level SD
  predict         -- HLog-quantized attention prediction (PAM)
  topk            -- row-wise top-k -> SPA + K/V column pruning
  similarity      -- fixed-window local similarity (critical/similar rows)
  mfi             -- Most-Frequent-Index FFN token sparsity
  spls            -- end-to-end plan builder (paper-reference raw-array API)
  planner         -- the unified planner: PlanContext + every plan driver
                     (exact / scan / progressive / streaming serving) and
                     the horizon-finalized column-vote policy
  sparse_exec     -- simulation- and capacity-mode sparse execution
  flops           -- exact FLOPs accounting (Fig. 15 reproduction)
"""

from .quantizers import (apot_project, hlog_bitlevel_decode,
                         hlog_bitlevel_encode, hlog_bitlevel_project,
                         hlog_levels, hlog_project, pot_project,
                         quantize_dequantize, symmetric_quantize)
from .predict import predict_qk, predicted_attention
from .topk import kv_keep_from_mask, row_topk_mask, sparsify_pam, topk_count
from .similarity import LocalSimilarity, local_similarity, windowed_l1
from .mfi import FFNSparsity, mfi_ffn_sparsity
from .spls import SPLSConfig, SparsityPlan, build_plan, plan_stats
from .planner import PlanContext
from .sparse_exec import (gather_rows, pack_by_mask, spls_attention,
                          spls_attention_packed, spls_ffn, spls_ffn_packed,
                          unpack_by_leader)
from .flops import ComponentFlops, dense_flops, reduction_report, spls_flops
