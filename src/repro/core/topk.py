"""Row-wise top-k pruning of the PAM -> Sparsified Predicted Attention (SPA).

The SPA keeps, for every attention row, only the ``ceil(k_ratio * L)``
largest predicted scores (intra-row sparsity).  It drives three things:
  * the intra-row attention mask used in the formal computation,
  * the inputs of the local-similarity stage (distances are computed on the
    SPA, not the dense PAM -- Sec. III-C explains why this *increases* Q
    sparsity),
  * K/V column pruning: columns that are empty in the SPA are dead.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["row_topk_mask", "sparsify_pam", "kv_keep_from_mask", "topk_count"]


def topk_count(L: int, k_ratio: float) -> int:
    """Number of kept entries per row; at least 1."""
    return max(1, min(L, math.ceil(k_ratio * L)))


def row_topk_mask(scores: jax.Array, k: int) -> jax.Array:
    """Boolean mask keeping exactly ``k`` largest entries of the last axis.

    Ties are broken by position (earlier wins), matching a hardware top-k
    unit that streams left-to-right.
    """
    L = scores.shape[-1]
    if k >= L:
        return jnp.ones_like(scores, dtype=bool)
    _, idx = jax.lax.top_k(scores, k)
    mask = jnp.zeros(scores.shape, dtype=bool)
    mask = jnp.put_along_axis(mask, idx, jnp.ones(idx.shape, dtype=bool),
                              axis=-1, inplace=False)
    return mask


def sparsify_pam(pam: jax.Array, k_ratio: float) -> Tuple[jax.Array, jax.Array]:
    """PAM -> (SPA values, boolean keep-mask).

    SPA has the dropped entries zeroed; the similarity stage treats "not
    selected" as exactly zero, which is what a hardware SPA buffer holds.
    """
    L = pam.shape[-1]
    k = topk_count(L, k_ratio)
    mask = row_topk_mask(pam, k)
    spa = jnp.where(mask, pam, jnp.zeros_like(pam))
    return spa, mask


def kv_keep_from_mask(mask: jax.Array) -> jax.Array:
    """Column-based K/V sparsification (Sec. III-C).

    A key/value position survives iff *any* SPA row references it.  Input
    mask: (..., H, L, L); output keep: (..., H, L) boolean over key positions.
    """
    return jnp.any(mask, axis=-2)
