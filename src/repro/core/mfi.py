"""Most-Frequent-Index (MFI) token similarity for FFN sparsification.

Sec. III-D: a token's similarity pattern differs across heads, so ESACT
represents each token by the critical-row index it maps to in every head,
takes the *mode* across heads (the MFI) and, if that index wins at least
``f`` head votes, declares the token similar to the MFI token: its FFN
output is not computed but copied from the MFI token's output.

Because leaders always live in the same fixed window as the token (local
similarity), the vote is over window-local offsets in ``[0, w)`` -- a cheap
one-hot histogram, exactly the counter array the hardware uses.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["FFNSparsity", "mfi_ffn_sparsity"]


class FFNSparsity(NamedTuple):
    is_critical: jax.Array  # (..., L) bool: FFN actually computed
    leader: jax.Array       # (..., L) int32: token whose FFN output is reused
    votes: jax.Array        # (..., L) int32: MFI vote count (diagnostic)


def mfi_ffn_sparsity(leader: jax.Array, w: int, f_threshold: int,
                     n_pointer_jumps: int = 3) -> FFNSparsity:
    """Token-level FFN sparsity from per-head attention leaders.

    Args:
      leader: (..., H, L) int32 global leader row per head (from
        :func:`repro.core.similarity.local_similarity`).
      w: window width (leaders are window-local).
      f_threshold: minimum vote count ``f``.  *Smaller* f -> more tokens pass
        the vote -> more FFN sparsity (Fig. 19).
      n_pointer_jumps: leader-chain flattening steps.  The MFI target of a
        similar token may itself be similar; we pointer-jump so every similar
        token ends on an FFN-critical token (ceil(log2(w)) hops suffice since
        leaders strictly precede followers inside a window).

    Returns per-token FFN sparsity over (..., L).
    """
    *lead, H, L = leader.shape
    off = leader % w                                  # window-local offsets
    votes_onehot = jax.nn.one_hot(off, w, dtype=jnp.int32)   # (..., H, L, w)
    counts = votes_onehot.sum(axis=-3)                       # (..., L, w)
    mfi_off = jnp.argmax(counts, axis=-1).astype(jnp.int32)  # (..., L)
    mfi_votes = jnp.max(counts, axis=-1)

    tok = jnp.arange(L, dtype=jnp.int32)
    tok = jnp.broadcast_to(tok, (*lead, L))
    window_base = (tok // w) * w
    mfi_global = jnp.minimum(window_base + mfi_off, jnp.int32(L - 1))

    similar = (mfi_votes >= f_threshold) & (mfi_global != tok)
    ffn_leader = jnp.where(similar, mfi_global, tok)

    # Flatten leader chains: a token must point at an FFN-critical token.
    for _ in range(n_pointer_jumps):
        ffn_leader = jnp.take_along_axis(ffn_leader, ffn_leader, axis=-1)
    is_crit = ffn_leader == tok
    return FFNSparsity(is_critical=is_crit, leader=ffn_leader, votes=mfi_votes)
