"""FLOPs accounting for the SPLS mechanism (reproduces Fig. 15's breakdown).

Counts multiply-accumulates x2 (one mul + one add = 2 FLOPs) for the three
transformer components the paper sparsifies -- QKV generation, attention
(QK^T and AV), and the FFN -- both dense and under a
:class:`~repro.core.spls.SparsityPlan`, plus the prediction overhead that
SPLS itself costs.  All counts are *exact* expectations over the plan masks,
matching how the paper's cycle simulator scales stage latencies by measured
sparsity ratios.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .spls import SparsityPlan

__all__ = ["ComponentFlops", "dense_flops", "spls_flops", "reduction_report"]


class ComponentFlops(NamedTuple):
    qkv: jax.Array        # Q,K,V projections (+ output projection)
    attention: jax.Array  # QK^T + AV
    ffn: jax.Array        # both FFN linears
    overhead: jax.Array   # SPLS prediction cost (0 for dense)

    @property
    def total(self):
        return self.qkv + self.attention + self.ffn + self.overhead


def dense_flops(B: int, L: int, D: int, H: int, d_ff: int,
                causal: bool = False) -> ComponentFlops:
    """Per-block dense FLOPs.  Attention counts the causal half if asked."""
    f = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
    qkv = 4 * 2.0 * B * L * D * D        # Wq, Wk, Wv, Wo
    attn_pairs = (L * (L + 1) / 2) if causal else float(L * L)
    attn = 2 * 2.0 * B * H * attn_pairs * (D // H)
    ffn = 2 * 2.0 * B * L * D * d_ff
    z = jnp.asarray(0.0, f)
    return ComponentFlops(jnp.asarray(qkv, f), jnp.asarray(attn, f),
                          jnp.asarray(ffn, f), z)


def spls_flops(plan: SparsityPlan, D: int, d_ff: int,
               include_overhead: bool = True) -> ComponentFlops:
    """FLOPs actually executed under ``plan``.

    QKV: Q rows generated only for per-head critical rows; K/V rows only for
    surviving columns; the output projection runs on recovered (full) rows
    because concatenation restores the shape -- the paper's dynamic
    allocation computes only critical Psums, so Wo is scaled by the mean
    critical fraction as well.
    Attention: each computed row costs its surviving mask entries (QK^T) and
    the same count again for AV.
    FFN: two linears on critical tokens only.
    Overhead: HLog prediction = two DxD-ish matmuls on X plus the predicted
    score matmul, at "addition cost".  We charge it at 1 FLOP per MAC (adds
    only -- the bit-level unit removes the multiplies) plus the L1
    similarity adds ``L^2 (w-1)`` -- conservative upper bound.
    """
    *lead, L, _ = plan.attn_mask.shape
    B = lead[0]
    Hh = 1
    for d in lead[1:]:
        Hh *= d
    Dh = D // Hh
    fq = plan.q_critical.astype(jnp.float32)
    fkv = plan.kv_keep.astype(jnp.float32)
    fffn = plan.ffn_critical.astype(jnp.float32)

    q_rows = fq.sum()                       # total critical rows over B,H
    kv_rows = fkv.sum()
    # Q projection is per-head slice (D x Dh per head); K/V likewise.
    qkv = 2.0 * (q_rows * D * Dh + 2.0 * kv_rows * D * Dh)
    # Wo runs on critical rows per head (dynamic allocation, Sec. IV-D)
    qkv = qkv + 2.0 * q_rows * Dh * D

    # attention: computed rows are the critical ones; each costs its mask row
    mask_rows = plan.attn_mask & plan.q_critical[..., None]
    pairs = mask_rows.astype(jnp.float32).sum()
    attn = 2 * 2.0 * pairs * Dh

    ffn = 2 * 2.0 * fffn.sum() * D * d_ff

    if include_overhead:
        # prediction matmuls (adds only): X@Wq', X@Wk' and Q'K'^T per head
        pred = (2.0 * B * L * D * D) + B * Hh * (L * (L + 1) / 2) * Dh
        sim = B * Hh * L * L  # L1 adds, <= L^2 (w-1) but on SPA rows
        overhead = jnp.asarray(pred + sim, jnp.float32)
    else:
        overhead = jnp.asarray(0.0, jnp.float32)
    return ComponentFlops(qkv, attn, ffn, overhead)


def reduction_report(plan: SparsityPlan, D: int, d_ff: int,
                     causal: bool = True) -> dict:
    """Fractional computation reduction per component + overall (Fig. 15)."""
    *lead, L, _ = plan.attn_mask.shape
    B, H = lead[0], 1
    for d in lead[1:]:
        H *= d
    dense = dense_flops(B, L, D, H, d_ff, causal=causal)
    sparse = spls_flops(plan, D, d_ff)
    red = lambda d, s: 1.0 - s / d
    return {
        "qkv_reduction": red(dense.qkv, sparse.qkv),
        "attention_reduction": red(dense.attention, sparse.attention),
        "ffn_reduction": red(dense.ffn, sparse.ffn),
        "overall_reduction": red(dense.total, sparse.total),
        "overhead_fraction": sparse.overhead / dense.total,
    }
