"""Compat shim: the serving engine moved to :mod:`repro.serving`.

The dense fixed-slot engine (:class:`ServingEngine`) and the block-pool
paged engine (:class:`PagedServingEngine`) now live in
``repro.serving.engine``; this module re-exports the public names so
existing imports (`from repro.runtime.serve import ...`) keep working.
"""

from repro.serving import (PagedServingEngine, Request, ServeConfig,
                           ServingEngine)

__all__ = ["Request", "ServeConfig", "ServingEngine", "PagedServingEngine"]
