"""Batched serving engine: continuous batching over a fixed-slot KV cache.

The engine owns ``n_slots`` cache rows.  Requests join free slots (prefill
writes their prompt KV), every engine tick decodes one token for all active
slots in a single batched ``serve_step``, and finished rows free their slot
for the next queued request -- the standard continuous-batching dataflow.
When SPLS is enabled, prefill runs the paper's sparse pipeline (where its
end-to-end computation reduction lands in serving).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import decode_step, init_cache, prefill
from repro.models.common import dtype_of

__all__ = ["Request", "ServeConfig", "ServingEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (Lp,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 4
    max_len: int = 256
    greedy: bool = True
    # attention backend override for this engine (None = cfg/auto); see
    # repro.models.attn_backend -- prefill resolves the forward side
    # (e.g. "pallas_flash"), ticks resolve the decode side.
    attn_backend: Optional[str] = None


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig):
        assert cfg.input_mode == "tokens", "engine serves token models"
        if scfg.attn_backend is not None:
            cfg = dataclasses.replace(cfg, attn_backend=scfg.attn_backend)
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self.queue: deque = deque()
        self.slots: List[Optional[Request]] = [None] * scfg.n_slots
        self.pos = jnp.zeros((scfg.n_slots,), jnp.int32)
        self.tokens = jnp.zeros((scfg.n_slots, 1), jnp.int32)
        self.cache = init_cache(cfg, scfg.n_slots, scfg.max_len)
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
        self._prefill = jax.jit(
            lambda p, toks: prefill(cfg, p, toks, max_len=scfg.max_len))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Move queued requests into free slots (prefill their prompt)."""
        for s in range(self.scfg.n_slots):
            if self.slots[s] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            lp = int(req.prompt.shape[0])
            logits, cache1 = self._prefill(self.params,
                                           req.prompt[None, :])
            # splice this row's prefilled cache into slot s
            self.cache = jax.tree.map(
                lambda full, one: full.at[:, s:s + 1].set(one),
                self.cache, cache1)
            nxt = int(jnp.argmax(logits[0, -1]))
            req.output.append(nxt)
            self.slots[s] = req
            self.pos = self.pos.at[s].set(lp)
            self.tokens = self.tokens.at[s, 0].set(nxt)

    def _retire(self) -> None:
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            hit_eos = req.eos_id is not None and req.output and \
                req.output[-1] == req.eos_id
            if len(req.output) >= req.max_new_tokens or hit_eos or \
                    int(self.pos[s]) >= self.scfg.max_len - 1:
                req.done = True
                self.slots[s] = None

    def tick(self) -> int:
        """One engine iteration; returns number of active slots decoded."""
        self._admit()
        active = [s for s, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens, self.pos)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        for s in active:
            tok = int(nxt[s])
            self.slots[s].output.append(tok)
        self.pos = self.pos + jnp.asarray(
            [1 if self.slots[s] is not None else 0
             for s in range(self.scfg.n_slots)], jnp.int32)
        self.tokens = nxt[:, None]
        self._retire()
        return len(active)

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        done: List[Request] = []
        seen: set = set()
        for _ in range(max_ticks):
            self.tick()
            if not self.queue and all(s is None for s in self.slots):
                break
        return done
