"""Deprecated compat shim: the serving engine moved to :mod:`repro.serving`.

Importing this module (or any attribute from it) emits a
``DeprecationWarning`` pointing at :mod:`repro.serving`.  Attribute access
forwards to ``repro.serving`` dynamically -- this module no longer keeps
its own copy of the export list, so it can never drift from what
``repro.serving.__init__`` actually owns.
"""

import warnings

# star-import surface of the old shim (module __getattr__ resolves each)
__all__ = ["Request", "ServeConfig", "ServingEngine", "PagedServingEngine"]

warnings.warn(
    "repro.runtime.serve is deprecated: the serving engines live in "
    "repro.serving (import Request/ServeConfig/ServingEngine/"
    "PagedServingEngine from there)", DeprecationWarning, stacklevel=2)


def __getattr__(name):
    from repro import serving

    if name in serving.__all__:
        warnings.warn(
            f"repro.runtime.serve.{name} is deprecated; import it from "
            f"repro.serving", DeprecationWarning, stacklevel=2)
        return getattr(serving, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    from repro import serving

    return sorted(set(globals()) | set(serving.__all__))
