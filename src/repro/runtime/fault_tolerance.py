"""Fault tolerance + straggler mitigation for long-running multi-pod jobs.

On a real 1000+-node deployment, failures arrive hourly; the framework's
contract is: (1) never lose more than the last checkpoint interval, (2)
detect dead/slow hosts fast, (3) restart elastically on fewer/more hosts.
The pieces here are runnable single-process (tested), and each maps 1:1 to
its cluster-scale implementation:

  * :class:`Heartbeat` -- per-host liveness with monotonic deadlines.  In a
    cluster this is backed by a KV store (etcd/GCS); here, by a dict.
  * :class:`StragglerDetector` -- per-step timing z-tests.  Hosts whose
    step time exceeds ``threshold x`` the rolling median are flagged for
    preemptive replacement (before they become hard failures).
  * :class:`FailureSimulator` -- deterministic fault injection used by the
    integration tests to prove the trainer's checkpoint/restart loop heals.
  * :func:`retry_with_backoff` -- the wrapper around anything that touches
    cross-host I/O.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

__all__ = ["Heartbeat", "StragglerDetector", "FailureSimulator",
           "retry_with_backoff"]


class Heartbeat:
    """Liveness tracking: hosts ping; anything silent past the timeout is
    declared dead and reported for eviction + elastic restart."""

    def __init__(self, timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last: Dict[str, float] = {}

    def ping(self, host: str) -> None:
        self._last[host] = self._clock()

    def dead_hosts(self) -> List[str]:
        now = self._clock()
        return [h for h, t in self._last.items()
                if now - t > self.timeout_s]

    def alive_hosts(self) -> List[str]:
        now = self._clock()
        return [h for h, t in self._last.items()
                if now - t <= self.timeout_s]


class StragglerDetector:
    """Rolling-median step-time watchdog.

    A host is a straggler if its last step took more than ``threshold``
    times the rolling median across hosts.  At scale this drives preemptive
    hot-spare swap-in; single-process it drives the trainer's metrics.
    """

    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self._times: Dict[str, deque] = {}

    def record(self, host: str, step_time_s: float) -> None:
        self._times.setdefault(host, deque(maxlen=self.window)).append(
            step_time_s)

    def _median(self, xs: List[float]) -> float:
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def global_median(self) -> Optional[float]:
        allt = [t for dq in self._times.values() for t in dq]
        return self._median(allt) if allt else None

    def stragglers(self) -> List[str]:
        med = self.global_median()
        if med is None or med <= 0:
            return []
        return [h for h, dq in self._times.items()
                if dq and dq[-1] > self.threshold * med]


@dataclasses.dataclass
class FailureSimulator:
    """Deterministic fault injection: raises at the configured steps."""

    fail_at_steps: tuple = ()
    error: type = RuntimeError
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise self.error(f"injected failure at step {step}")


def retry_with_backoff(fn: Callable, max_retries: int = 3,
                       base_delay_s: float = 0.1,
                       retriable=(OSError, IOError, RuntimeError),
                       sleep: Callable[[float], None] = time.sleep):
    """Run ``fn()`` with exponential backoff on retriable errors."""
    last = None
    for attempt in range(max_retries + 1):
        try:
            return fn()
        except retriable as e:  # noqa: PERF203
            last = e
            if attempt == max_retries:
                raise
            sleep(base_delay_s * (2 ** attempt))
    raise last  # unreachable
