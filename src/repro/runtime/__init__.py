"""Runtime: trainer loop, fault tolerance, elastic re-meshing, serving."""

from .fault_tolerance import (FailureSimulator, Heartbeat, StragglerDetector,
                              retry_with_backoff)
from .trainer import Trainer, TrainerConfig, train_loop
from .elastic import ElasticPlan, plan_elastic_mesh, rescale_batch
