"""Elastic scaling: re-mesh on node loss/gain and re-shard the state.

The protocol at cluster scale:
  1. Heartbeat declares hosts dead -> the coordinator computes the largest
     usable mesh from surviving hosts (:func:`plan_elastic_mesh`);
  2. every survivor restores the last committed checkpoint with the *new*
     mesh's shardings (``restore_checkpoint(..., shardings=...)``) -- the
     manifest is mesh-agnostic, so this is just a different device_put;
  3. the data pipeline resumes from the stored data step, with the global
     batch kept constant (per-host batch grows) or rescaled by policy.

Single-process we validate steps 1-3 with host-count arithmetic + re-shard
round-trips over different CPU mesh shapes (see tests/test_runtime.py).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["ElasticPlan", "plan_elastic_mesh", "rescale_batch"]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    dropped_hosts: Tuple[str, ...]
    note: str


def _largest_pow2_leq(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def plan_elastic_mesh(alive: List[str], chips_per_host: int,
                      model_parallel: int,
                      prev_data: Optional[int] = None) -> ElasticPlan:
    """Largest (data, model) mesh from surviving hosts.

    Model parallelism is fixed (it is baked into layer shardings and wants
    full ICI rings); the data axis absorbs the loss, rounded down to a
    power of two so microbatching stays divisible.
    """
    chips = len(alive) * chips_per_host
    if chips < model_parallel:
        raise RuntimeError(
            f"only {chips} chips alive; cannot sustain model={model_parallel}")
    data = _largest_pow2_leq(chips // model_parallel)
    note = "full" if prev_data in (None, data) else (
        f"degraded data {prev_data} -> {data}")
    return ElasticPlan(data=data, model=model_parallel, dropped_hosts=(),
                       note=note)


def rescale_batch(global_batch: int, old_data: int, new_data: int,
                  policy: str = "keep_global") -> int:
    """Batch policy after a re-mesh.

    keep_global: per-shard batch grows (gradient math unchanged).
    keep_per_shard: global batch shrinks proportionally (throughput-true,
    requires an LR rescale by the caller).
    """
    if policy == "keep_global":
        if global_batch % new_data:
            raise ValueError(
                f"global batch {global_batch} not divisible by data={new_data}")
        return global_batch
    if policy == "keep_per_shard":
        return global_batch * new_data // old_data
    raise ValueError(policy)
