"""The production training loop: checkpoint/restart, failure healing,
straggler tracking, elastic re-meshing, optional gradient compression.

Single-process it drives real CPU training (the examples + integration
tests); the same loop structure is what a multi-host launcher would run per
host, with the Heartbeat/StragglerDetector backed by a cluster KV store.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import (latest_step, restore_checkpoint,
                                   save_checkpoint)
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.optim.schedules import warmup_cosine
from repro.runtime.fault_tolerance import (FailureSimulator, Heartbeat,
                                           StragglerDetector,
                                           retry_with_backoff)
from repro.sharding.logical import axis_rules
from repro.sharding.rules import activation_rules

__all__ = ["TrainerConfig", "Trainer", "train_loop"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    peak_lr: float = 3e-4
    warmup_steps: int = 20
    n_micro: int = 1
    seed: int = 0
    keep_checkpoints: int = 3
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class Trainer:
    """Owns the (params, opt_state, step) triple and the healing loop."""

    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig,
                 data_cfg: DataConfig, mesh=None,
                 failure_sim: Optional[FailureSimulator] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.data_cfg = data_cfg
        self.mesh = mesh
        self.failure_sim = failure_sim
        self.heartbeat = Heartbeat(timeout_s=300.0)
        self.stragglers = StragglerDetector()
        self.metrics_log: list = []

        sched = warmup_cosine(tcfg.peak_lr, tcfg.warmup_steps,
                              tcfg.total_steps)
        step_fn = make_train_step(cfg, tcfg.opt, sched, tcfg.n_micro)
        self._train_step = jax.jit(step_fn, donate_argnums=(0, 1))

        self.params = None
        self.opt_state = None
        self.step = 0

    # ------------------------------------------------------------------
    def init_state(self) -> None:
        self.params = init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        self.opt_state = adamw_init(self.tcfg.opt, self.params)
        self.step = 0

    def restore_or_init(self) -> None:
        d = self.tcfg.ckpt_dir
        if d and latest_step(d) is not None:
            self.init_state()  # structure template
            state = {"params": self.params, "opt": self.opt_state}
            state, step, data_step = restore_checkpoint(d, state)
            self.params, self.opt_state = state["params"], state["opt"]
            self.step = step
        else:
            self.init_state()

    def save(self) -> None:
        if not self.tcfg.ckpt_dir:
            return
        retry_with_backoff(lambda: save_checkpoint(
            self.tcfg.ckpt_dir, self.step,
            {"params": self.params, "opt": self.opt_state},
            data_step=self.step, keep=self.tcfg.keep_checkpoints))

    # ------------------------------------------------------------------
    def run(self, host: str = "host0") -> Dict[str, Any]:
        """Run to ``total_steps``, healing injected failures by restoring
        the last checkpoint (the integration tests exercise this path)."""
        ctx = (axis_rules(activation_rules(self.mesh), self.mesh)
               if self.mesh is not None else _null_ctx())
        with ctx:
            if self.params is None:
                self.restore_or_init()
            while self.step < self.tcfg.total_steps:
                try:
                    t0 = time.monotonic()
                    if self.failure_sim is not None:
                        self.failure_sim.maybe_fail(self.step)
                    batch = synthetic_batch(self.data_cfg, self.step)
                    self.params, self.opt_state, metrics = self._train_step(
                        self.params, self.opt_state, batch)
                    dt = time.monotonic() - t0
                    self.heartbeat.ping(host)
                    self.stragglers.record(host, dt)
                    self.step += 1
                    if self.step % self.tcfg.log_every == 0 or \
                            self.step == self.tcfg.total_steps:
                        m = {k: float(v) for k, v in metrics.items()}
                        m["step"] = self.step
                        m["step_time_s"] = dt
                        self.metrics_log.append(m)
                    if self.tcfg.ckpt_dir and \
                            self.step % self.tcfg.ckpt_every == 0:
                        self.save()
                except Exception as e:  # noqa: BLE001 -- heal-or-die loop
                    if self.tcfg.ckpt_dir and latest_step(
                            self.tcfg.ckpt_dir) is not None:
                        # node failure: restore and continue (params/opt may
                        # have been donated mid-step -- rebuild structure)
                        self.params = None
                        self.restore_or_init()
                        continue
                    raise
            self.save()
        return {"final_step": self.step, "metrics": self.metrics_log}


def _null_ctx():
    import contextlib
    return contextlib.nullcontext()


def train_loop(cfg: ArchConfig, tcfg: TrainerConfig, data_cfg: DataConfig,
               mesh=None, failure_sim=None) -> Dict[str, Any]:
    return Trainer(cfg, tcfg, data_cfg, mesh, failure_sim).run()
