"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantizers import hlog_project

__all__ = ["hlog_qmatmul_ref", "flash_attention_ref",
           "local_similarity_ref", "flash_decode_ref", "paged_decode_ref",
           "gathered_matmul_ref"]


def hlog_qmatmul_ref(xq: jax.Array, wq: jax.Array) -> jax.Array:
    """HLog-projected matmul on integer-valued inputs.

    xq: (M, K) int-valued float32 (post 8-bit symmetric quantization);
    wq: (K, N) likewise.  Returns hlog(xq) @ hlog(wq) in float32 -- the PAM
    prediction matmul of Sec. IV-B, numerically identical to the bit-level
    SD/SJA/converter datapath.
    """
    return hlog_project(xq) @ hlog_project(wq)


def gathered_matmul_ref(x: jax.Array, w: jax.Array, perm: jax.Array,
                        src_slot: Optional[jax.Array] = None) -> jax.Array:
    """Pack-then-matmul(-then-scatter) oracle for ``gathered_matmul``.

    x: (L, D); w: (D, F); perm: (C,) packed source rows; src_slot: optional
    (M,) packed slot each output row reads.  This is exactly the XLA
    ``pack_by_mask``/``unpack_by_leader`` execution the kernel fuses.
    """
    out = x[perm].astype(jnp.float32) @ w.astype(jnp.float32)
    return out if src_slot is None else out[src_slot]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        kv_keep: Optional[jax.Array] = None) -> jax.Array:
    """Dense-softmax oracle.  q,k,v: (B, H, L, Dh); kv_keep: (B, H, Lk)."""
    B, H, L, Dh = q.shape
    Lk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * (Dh ** -0.5)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    i = jnp.arange(L)[:, None]
    j = jnp.arange(Lk)[None, :]
    m = (j <= i) if causal else jnp.ones((L, Lk), bool)
    if window is not None:
        m = m & (i - j < window)
        if not causal:  # symmetric window, matching the model's band mask
            m = m & (j - i < window)
    if kv_keep is not None:
        m = m & kv_keep[:, :, None, :]
    s = jnp.where(m, s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    a = jnp.where(jnp.isnan(a), 0.0, a)  # fully-masked rows -> zero output
    return jnp.einsum("bhqk,bhkd->bhqd", a, v.astype(jnp.float32)
                      ).astype(q.dtype)


def local_similarity_ref(spa: jax.Array, w: int) -> jax.Array:
    """Windowed pairwise (unnormalized) L1 distances.

    spa: (B, H, L, Lk) with L % w == 0 -> (B, H, L//w, w, w) float32.
    """
    B, H, L, Lk = spa.shape
    assert L % w == 0
    xp = spa.reshape(B, H, L // w, w, Lk).astype(jnp.float32)
    return jnp.abs(xp[..., :, None, :] - xp[..., None, :, :]).sum(-1)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, softcap: Optional[float] = None,
                     window: Optional[int] = None) -> jax.Array:
    """Dense decode oracle.  q: (B, KV, G, Dh); k/v: (B, KV, S, Dh)."""
    S = k.shape[2]
    Dh = q.shape[-1]
    s = jnp.einsum("bkgd,bkld->bkgl", q, k).astype(jnp.float32) * Dh ** -0.5
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    j = jnp.arange(S)
    m = j[None, :] <= pos[:, None]
    if window is not None:
        m = m & (pos[:, None] - j[None, :] < window)
    s = jnp.where(m[:, None, None, :], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    a = jnp.where(jnp.isnan(a), 0.0, a)
    return jnp.einsum("bkgl,bkld->bkgd", a,
                      v.astype(jnp.float32)).astype(q.dtype)


def paged_decode_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     pos_pages: jax.Array, tables: jax.Array,
                     kv_len: jax.Array, pos: jax.Array,
                     softcap: Optional[float] = None,
                     window: Optional[int] = None) -> jax.Array:
    """Gather-then-dense oracle for the paged decode kernels.

    q: (B, KV, G, Dh); k/v_pages: (KV, N, ps, Dh); pos_pages: (N, ps);
    tables: (B, P); kv_len: written slots per row; pos: original position of
    the current token (window upper bound).
    """
    B, KV, G, Dh = q.shape
    ps = k_pages.shape[2]
    P = tables.shape[1]
    S = P * ps
    kg = jnp.moveaxis(k_pages[:, tables], 1, 0).reshape(B, KV, S, Dh)
    vg = jnp.moveaxis(v_pages[:, tables], 1, 0).reshape(B, KV, S, Dh)
    pg = pos_pages[tables].reshape(B, S)
    s = jnp.einsum("bkgd,bkld->bkgl", q, kg).astype(jnp.float32) * Dh ** -0.5
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    slot = jnp.arange(S)[None, :]
    m = slot < kv_len[:, None]
    if window is not None:
        m = m & (pos[:, None] - pg < window)
    s = jnp.where(m[:, None, None, :], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    a = jnp.where(jnp.isnan(a), 0.0, a)
    return jnp.einsum("bkgl,bkld->bkgd", a,
                      vg.astype(jnp.float32)).astype(q.dtype)
