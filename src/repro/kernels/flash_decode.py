"""Pallas TPU kernel: single-token flash decode against a *contiguous*
(slot-per-request) KV cache.

Decode cells (decode_32k / long_500k) are memory-bound: one query token
reads the whole KV cache.  The kernel streams the cache through VMEM in
``bk`` chunks with the online-softmax recurrence, honouring the write
position (`pos`) and an optional sliding window -- SWA decodes touch only
``window`` positions, which is what makes h2o/gemma2 long_500k cells
sub-quadratic in practice.

For the block-pool *paged* variant (per-request block tables over a shared
page pool, as used by ``repro.serving``) see
``repro.kernels.paged_decode.paged_flash_decode``.

Grid: (B*KV, S/bk); one program row per (batch, kv-head); the G query
heads of the group are carried together in the q tile (they share the K/V
reads -- the whole point of GQA at decode time).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_decode"]

_NEG = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, softcap, window, bk, nk):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0]
    k_start = ik * bk
    live = k_start <= pos
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > pos - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (G, Dh)
        k = k_ref[0].astype(jnp.float32)          # (bk, Dh)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        j = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = j <= pos
        if window is not None:
            mask &= pos - j < window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
        l_scr[...] = l_scr[...] * corr + p.sum(-1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "window", "block_k",
                                             "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array,
                 softcap: Optional[float] = None,
                 window: Optional[int] = None, block_k: int = 512,
                 interpret: bool = True) -> jax.Array:
    """q: (B, KV, G, Dh) one token per row; k/v: (B, KV, S, Dh) caches;
    pos: (B,) current write index (inclusive).  Returns (B, KV, G, Dh)."""
    B, KV, G, Dh = q.shape
    S = k.shape[2]
    bk = min(block_k, S)
    assert S % bk == 0
    nk = S // bk
    scale = Dh ** -0.5

    qf = q.reshape(B * KV, G, Dh)
    kf = k.reshape(B * KV, S, Dh)
    vf = v.reshape(B * KV, S, Dh)
    posf = jnp.repeat(pos, KV)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, softcap=softcap,
                          window=window, bk=bk, nk=nk),
        grid=(B * KV, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,)),
            pl.BlockSpec((1, G, Dh), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, Dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dh), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, Dh), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(posf, qf, kf, vf)
    return out.reshape(B, KV, G, Dh)
