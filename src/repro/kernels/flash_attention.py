"""Pallas TPU kernel: blockwise flash attention with SPLS support.

Online-softmax attention tiled for VMEM, with the features the assigned
archs + the paper's technique need:

  * causal and sliding-window (gemma2 / h2o-danube / jamba) masking with
    *block-level skipping* -- fully-masked (q-block, k-block) pairs are never
    computed, so SWA cost is O(L * window), not O(L^2);
  * gemma2-style logit soft-capping;
  * an optional per-position ``kv_keep`` mask -- the SPLS column-pruning
    mask (zero SPA columns).  Dead KV blocks (all-False) are skipped whole,
    which is exactly how the accelerator's column sparsity maps onto a tiled
    TPU kernel: structured block skips instead of per-element clock gating;
  * an optional per-row ``q_pos`` index map -- the original sequence
    position of each (possibly packed) query row.  This is what lets the
    SPLS row sparsity (critical rows packed to capacity, similar rows
    recovered from their leader) run through the kernel: causal and window
    masks are evaluated against the original positions, and the causal /
    window block-skip predicates use the min/max position in the q tile;
  * ragged lengths: ``Lq % block_q != 0`` / ``Lk % block_k != 0`` are
    handled by zero-padding; padded K columns are killed through the keep
    mask and padded Q rows are sliced off the output.

Grid: (B*H, Lq/bq, Lk/bk), K innermost.  Running max / denominator / output
accumulator live in VMEM scratch and are rescaled per K step; the output is
written once on the final K step.

Block-skip boundary conventions (audited against ``ref.flash_attention_ref``
by ``tests/test_kernels.py::TestFlashAttentionBoundaries``):

  * causal keeps (i, j) iff ``j <= i``; a K block starting at ``k_start`` is
    live iff ``k_start <= max(q_pos in block)`` (block-index path:
    ``q_start + bq - 1``);
  * window keeps (i, j) iff ``i - j < window``; with ``causal=False`` the
    window is symmetric (``|i - j| < window``), matching the XLA band mask.
    A K block is live iff its last column
    ``k_start + bk - 1 > min(q_pos) - window`` (and, non-causal, its first
    column ``k_start < max(q_pos) + window``);
  * a keep-masked K block is live iff any keep bit in it is set.

Each predicate is exact for its own mask, and the conjunction is safe
because the per-row live column sets are contiguous and overlap across
consecutive rows, so a block passing every block-level test always contains
at least one live (i, j) pair.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG = -1e30


def _make_kernel(*, scale, causal, window, softcap, bq, bk, nk,
                 has_qpos, has_keep):
    """Build a kernel body for the given optional-input combination.

    Ref order: q, k, v, [q_pos], [kv_keep], o, then scratch (m, l, acc).
    """

    def kernel(*refs):
        q_ref, k_ref, v_ref = refs[:3]
        idx = 3
        qpos_ref = None
        if has_qpos:
            qpos_ref = refs[idx]
            idx += 1
        keep_ref = None
        if has_keep:
            keep_ref = refs[idx]
            idx += 1
        o_ref, m_scr, l_scr, acc_scr = refs[idx:idx + 4]

        ik = pl.program_id(2)
        iq = pl.program_id(1)

        @pl.when(ik == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, _NEG)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        q_start = iq * bq
        k_start = ik * bk
        if has_qpos:
            qpos = qpos_ref[0]                       # (bq,) original row ids
            q_lo, q_hi = jnp.min(qpos), jnp.max(qpos)
        else:
            q_lo, q_hi = q_start, q_start + bq - 1
        # block-level skip: causal (k block entirely in the future) and
        # window (k block entirely behind the window of every q row here)
        live = True
        if causal:
            live = jnp.logical_and(live, k_start <= q_hi)
        if window is not None:
            live = jnp.logical_and(live, k_start + bk - 1 > q_lo - window)
            if not causal:  # symmetric window: future side masks too
                live = jnp.logical_and(live, k_start < q_hi + window)
        if keep_ref is not None:
            live = jnp.logical_and(live, jnp.any(keep_ref[0] > 0))

        @pl.when(live)
        def _compute():
            q = q_ref[0].astype(jnp.float32)
            k = k_ref[0].astype(jnp.float32)
            v = v_ref[0].astype(jnp.float32)
            s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = jnp.tanh(s / softcap) * softcap
            if has_qpos:
                qi = jnp.broadcast_to(qpos[:, None], (bq, bk))
            else:
                qi = q_start + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, bk), 0)
            kj = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= kj <= qi
            if window is not None:
                mask &= qi - kj < window
                if not causal:
                    mask &= kj - qi < window
            if keep_ref is not None:
                mask &= (keep_ref[0] > 0)[None, :]
            s = jnp.where(mask, s, _NEG)

            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            corr = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
            l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
            acc_scr[...] = (acc_scr[...] * corr[:, None]
                            + jnp.dot(p, v,
                                      preferred_element_type=jnp.float32))
            m_scr[...] = m_new

        @pl.when(ik == nk - 1)
        def _finalize():
            l = l_scr[...]
            safe = jnp.where(l > 0, l, 1.0)
            o_ref[0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    kv_keep: Optional[jax.Array] = None,
                    q_pos: Optional[jax.Array] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, Lq, Dh); k, v: (B, H, Lk, Dh) or GQA-grouped
    (B, KV, Lk, Dh) with H % KV == 0 -- grouped K/V is read through the
    BlockSpec index map (head h -> group h // G), never materialized
    H-wide.  kv_keep: optional (B, H, Lk) bool (per *query* head -- SPLS
    prunes per head).  q_pos: optional (B, H, Lq) int32 original position
    of each query row (for SPLS-packed rows); defaults to arange semantics
    when omitted.  Ragged Lq/Lk are padded internally."""
    B, H, Lq, Dh = q.shape
    KVh, Lk = k.shape[1], k.shape[2]
    assert H % KVh == 0, (H, KVh)
    G = H // KVh
    bq, bk = min(block_q, Lq), min(block_k, Lk)
    pad_q, pad_k = (-Lq) % bq, (-Lk) % bk

    if pad_k and kv_keep is None:
        # the keep mask doubles as the padded-column kill switch
        kv_keep = jnp.ones((B, H, Lk), bool)
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        if q_pos is not None:
            # padded rows repeat the last real position (edge mode), so the
            # min/max over a q tile -- and with it block liveness -- is
            # exactly what the real rows imply; their outputs are sliced off
            q_pos = jnp.pad(q_pos, ((0, 0), (0, 0), (0, pad_q)),
                            mode="edge")
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        kv_keep = jnp.pad(kv_keep, ((0, 0), (0, 0), (0, pad_k)))
    Lqp, Lkp = Lq + pad_q, Lk + pad_k
    nq, nk = Lqp // bq, Lkp // bk
    scale = Dh ** -0.5

    # flat program id b = (batch * KV + kv) * G + g, so b // G addresses the
    # grouped K/V row -- GQA sharing via the index map, no repeated copies
    args = [q.reshape(B * H, Lqp, Dh),
            k.reshape(B * KVh, Lkp, Dh),
            v.reshape(B * KVh, Lkp, Dh)]
    in_specs = [
        pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, bk, Dh), lambda b, i, j: (b // G, j, 0)),
        pl.BlockSpec((1, bk, Dh), lambda b, i, j: (b // G, j, 0)),
    ]
    if q_pos is not None:
        args.append(q_pos.reshape(B * H, Lqp).astype(jnp.int32))
        in_specs.append(pl.BlockSpec((1, bq), lambda b, i, j: (b, i)))
    if kv_keep is not None:
        args.append(kv_keep.reshape(B * H, Lkp).astype(jnp.int32))
        in_specs.append(pl.BlockSpec((1, bk), lambda b, i, j: (b, j)))

    kernel = _make_kernel(scale=scale, causal=causal, window=window,
                          softcap=softcap, bq=bq, bk=bk, nk=nk,
                          has_qpos=q_pos is not None,
                          has_keep=kv_keep is not None)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Lqp, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return out.reshape(B, H, Lqp, Dh)[:, :, :Lq]
