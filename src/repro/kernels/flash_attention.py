"""Pallas TPU kernel: blockwise flash attention with SPLS support.

Online-softmax attention tiled for VMEM, with the features the assigned
archs + the paper's technique need:

  * causal and sliding-window (gemma2 / h2o-danube / jamba) masking with
    *block-level skipping* -- fully-masked (q-block, k-block) pairs are never
    computed, so SWA cost is O(L * window), not O(L^2);
  * gemma2-style logit soft-capping;
  * an optional per-position ``kv_keep`` mask -- the SPLS column-pruning
    mask (zero SPA columns).  Dead KV blocks (all-False) are skipped whole,
    which is exactly how the accelerator's column sparsity maps onto a tiled
    TPU kernel: structured block skips instead of per-element clock gating.

Grid: (B*H, Lq/bq, Lk/bk), K innermost.  Running max / denominator / output
accumulator live in VMEM scratch and are rescaled per K step; the output is
written once on the final K step.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, keep_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale, causal, window, softcap,
            bq, bk, nk):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    # block-level skip: causal (k block entirely in the future) and window
    # (k block entirely behind the window of every q row in this block)
    live = True
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)
    if keep_ref is not None:
        live = jnp.logical_and(live, jnp.any(keep_ref[0] > 0))

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kj <= qi
        if window is not None:
            mask &= qi - kj < window
        if keep_ref is not None:
            mask &= (keep_ref[0] > 0)[None, :]
        s = jnp.where(mask, s, _NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    kv_keep: Optional[jax.Array] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q, k, v: (B, H, L, Dh); kv_keep: optional (B, H, Lk) bool."""
    B, H, Lq, Dh = q.shape
    Lk = k.shape[2]
    bq, bk = min(block_q, Lq), min(block_k, Lk)
    assert Lq % bq == 0 and Lk % bk == 0
    nq, nk = Lq // bq, Lk // bk
    scale = Dh ** -0.5

    qf = q.reshape(B * H, Lq, Dh)
    kf = k.reshape(B * H, Lk, Dh)
    vf = v.reshape(B * H, Lk, Dh)
    args = [qf, kf, vf]
    in_specs = [
        pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, bk, Dh), lambda b, i, j: (b, j, 0)),
        pl.BlockSpec((1, bk, Dh), lambda b, i, j: (b, j, 0)),
    ]
    if kv_keep is not None:
        args.append(kv_keep.reshape(B * H, Lk).astype(jnp.int32))
        in_specs.append(pl.BlockSpec((1, bk), lambda b, i, j: (b, j)))
        kernel = functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, bq=bq, bk=bk, nk=nk)
    else:
        def kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
            _kernel(q_ref, k_ref, v_ref, None, o_ref, m_scr, l_scr, acc_scr,
                    scale=scale, causal=causal, window=window,
                    softcap=softcap, bq=bq, bk=bk, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Lq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return out.reshape(B, H, Lq, Dh)
