"""Pallas TPU kernel: fused HLog projection + prediction matmul.

The ASIC's bit-level prediction unit (Sec. IV-B) performs HLog quantization
with a shift detector and replaces the multiplies of the prediction matmul
with exponent additions.  A TPU has no scalar shift-add datapath that can
beat the MXU, so the TPU-native adaptation (DESIGN.md) fuses the *numerics*:
the HLog projection of both operands happens in VMEM registers (VPU, a few
float ops per element -- cheaper than an HBM round-trip for a quantized
copy) immediately followed by the MXU matmul of the projected tiles.  The
win vs. the naive pipeline is one fused pass instead of
project -> materialize -> matmul, i.e. 2x fewer HBM reads of X/W.

Grid: (M/bm, N/bn, K/bk) with K innermost; the output tile is revisited and
accumulated across K steps (initialised at k == 0).  All tiles live in VMEM
via BlockSpec; bm/bn/bk default to MXU-aligned 128 multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["hlog_qmatmul"]


def _hlog_project_inkernel(q: jax.Array) -> jax.Array:
    """Branch-free HLog projection of integer-valued floats (VPU ops).

    mag = |q| = 2^m * r with r in [1, 2):
      r < 1.25 -> 2^m ; 1.25 <= r < 1.75 -> 1.5 * 2^m ; r >= 1.75 -> 2^{m+1}
    Ties already round up because the comparisons are `<`.  Exact for the
    int8 grid (see tests vs. the bit-level encoder).
    """
    mag = jnp.abs(q)
    safe = jnp.maximum(mag, 1.0)
    m = jnp.floor(jnp.log2(safe))
    p = jnp.exp2(m)
    r = safe / p
    lvl = jnp.where(r < 1.25, p, jnp.where(r < 1.75, 1.5 * p, 2.0 * p))
    return jnp.where(mag == 0, 0.0, jnp.sign(q) * lvl)


def _kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xt = _hlog_project_inkernel(x_ref[...].astype(jnp.float32))
    wt = _hlog_project_inkernel(w_ref[...].astype(jnp.float32))
    o_ref[...] += jnp.dot(xt, wt, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def hlog_qmatmul(xq: jax.Array, wq: jax.Array, bm: int = 128, bn: int = 128,
                 bk: int = 128, interpret: bool = True) -> jax.Array:
    """hlog(xq) @ hlog(wq).  xq: (M, K); wq: (K, N); int-valued float32.

    Shapes must tile evenly (callers pad); VMEM per step is
    ``bm*bk + bk*bn + bm*bn`` floats (default 192 KiB), well inside the
    ~16 MiB v5e VMEM even with double buffering.
    """
    M, K = xq.shape
    K2, N = wq.shape
    assert K == K2, (xq.shape, wq.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"({M},{K})x({K},{N}) not tileable by ({bm},{bn},{bk})"

    return pl.pallas_call(
        _kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(xq, wq)
