"""Jit'd public wrappers over the Pallas kernels.

On a real TPU these dispatch the compiled kernels (``interpret=False``); on
CPU (this container) they run the kernel bodies in interpret mode, which is
bit-accurate but slow -- the tests validate against the pure-jnp oracles in
``ref.py`` either way.  ``use_pallas=False`` falls straight through to the
reference implementation (the default inside the model code, where XLA's own
fusion is already near-roofline for dense shapes; the kernels matter on TPU
for the SPLS-sparse and SWA paths).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .hlog_qmatmul import hlog_qmatmul
from .local_similarity import local_similarity_dist

__all__ = ["predict_matmul", "attention", "window_distances",
           "flash_attention", "hlog_qmatmul", "local_similarity_dist"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def predict_matmul(xq: jax.Array, wq: jax.Array,
                   use_pallas: bool = True) -> jax.Array:
    """Fused HLog-project + matmul (PAM prediction hot spot)."""
    M, K = xq.shape
    N = wq.shape[1]
    tileable = M % 128 == 0 and N % 128 == 0 and K % 128 == 0
    if use_pallas and tileable:
        return hlog_qmatmul(xq, wq, interpret=not _on_tpu())
    return ref.hlog_qmatmul_ref(xq, wq)


def attention(q, k, v, causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None,
              kv_keep: Optional[jax.Array] = None,
              use_pallas: bool = True) -> jax.Array:
    """Flash attention with SWA / softcap / SPLS column mask."""
    L, Lk = q.shape[2], k.shape[2]
    tileable = L % 128 == 0 and Lk % 128 == 0
    if use_pallas and tileable:
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, kv_keep=kv_keep,
                               interpret=not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap, kv_keep=kv_keep)


def window_distances(spa: jax.Array, w: int = 8,
                     use_pallas: bool = True) -> jax.Array:
    """Windowed pairwise L1 distances (similarity-unit hot spot)."""
    L, Lk = spa.shape[2], spa.shape[3]
    if use_pallas and L % w == 0 and Lk % 128 == 0:
        return local_similarity_dist(spa, w=w, interpret=not _on_tpu())
    return ref.local_similarity_ref(spa, w)
