"""Pallas TPU kernel: single-token flash decode against a *paged* KV cache.

The serving engine (``repro.serving``) stores KV in a block pool of
fixed-size pages instead of one dense ``n_slots x max_len`` cache.  This
kernel is the decode path of that layout: one query token per sequence
attends over its pages, gathered through a per-sequence block table.

Layout (see ``src/repro/serving/README.md`` for the lifecycle):

  k_pages / v_pages: (KV, n_pages, page_size, Dh)  -- the shared pool; page
      0 is the reserved *null page* (block-table filler / write sink for
      inactive batch rows; reads of it are always masked out).
  pos_pages:         (n_pages, page_size) int32    -- original token
      position of every written slot.  Once SPLS page pruning has compacted
      a sequence, slot index != token position, so sliding-window masks must
      consult these ids.
  tables:            (B, P) int32                  -- block tables (physical
      page id per logical page); unallocated entries hold the null page.
  kv_len:            (B,) int32                    -- written slots per row.
  pos:               (B,) int32                    -- original position of
      the current query token (inclusive upper bound of the window).

Grid: (B*KV, P).  The block table, lengths, and positions ride in as
scalar-prefetch operands, so each grid step's BlockSpec index map resolves
the *physical* page to bring into VMEM -- the gather happens in the DMA
schedule and no contiguous cache is ever materialized.  The online-softmax
recurrence is the same as ``flash_decode``; pages past ``kv_len`` (and, with
a window, pages whose slots all fell out of the window) are skipped whole.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_flash_decode", "NULL_PAGE"]

NULL_PAGE = 0
_NEG = -1e30


def _kernel(bt_ref, kl_ref, cp_ref, q_ref, k_ref, v_ref, pp_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale, softcap, window, ps, kv, np_):
    i = pl.program_id(0)
    j = pl.program_id(1)
    b = i // kv

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    n_valid = kl_ref[b]
    slot0 = j * ps
    live = slot0 < n_valid
    if window is not None:
        # page-level window skip: a page is dead once every *written* slot
        # has aged out of the window (original ids, not slot indices)
        sl = slot0 + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        in_w = (sl < n_valid) & (cp_ref[b] - pp_ref[0][None, :] < window)
        live = jnp.logical_and(live, in_w.any())

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (G, Dh)
        k = k_ref[0, 0].astype(jnp.float32)       # (ps, Dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        slot = slot0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = slot < n_valid
        if window is not None:
            mask &= cp_ref[b] - pp_ref[0][None, :] < window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
        l_scr[...] = l_scr[...] * corr + p.sum(-1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(j == np_ - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "window", "interpret"))
def paged_flash_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       pos_pages: jax.Array, tables: jax.Array,
                       kv_len: jax.Array, pos: jax.Array,
                       softcap: Optional[float] = None,
                       window: Optional[int] = None,
                       interpret: bool = True) -> jax.Array:
    """q: (B, KV, G, Dh) one token per sequence; k/v_pages: (KV, N, ps, Dh);
    pos_pages: (N, ps); tables: (B, P); kv_len/pos: (B,).
    Returns (B, KV, G, Dh)."""
    B, KV, G, Dh = q.shape
    _, N, ps, _ = k_pages.shape
    P = tables.shape[1]
    scale = Dh ** -0.5
    qf = q.reshape(B * KV, G, Dh)
    tables = tables.astype(jnp.int32)
    kv_len = kv_len.astype(jnp.int32)
    pos = pos.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B * KV, P),
        in_specs=[
            pl.BlockSpec((1, G, Dh), lambda i, j, bt, kl, cp: (i, 0, 0)),
            pl.BlockSpec((1, 1, ps, Dh),
                         lambda i, j, bt, kl, cp: (i % KV, bt[i // KV, j],
                                                   0, 0)),
            pl.BlockSpec((1, 1, ps, Dh),
                         lambda i, j, bt, kl, cp: (i % KV, bt[i // KV, j],
                                                   0, 0)),
            pl.BlockSpec((1, ps),
                         lambda i, j, bt, kl, cp: (bt[i // KV, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, G, Dh), lambda i, j, bt, kl, cp: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, softcap=softcap,
                          window=window, ps=ps, kv=KV, np_=P),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, G, Dh), q.dtype),
        interpret=interpret,
    )(tables, kv_len, pos, qf, k_pages, v_pages, pos_pages)
    return out.reshape(B, KV, G, Dh)
