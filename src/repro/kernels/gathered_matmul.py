"""Pallas TPU kernel: fused gather-by-row-index -> MXU matmul.

The TPU-native realization of ESACT's dynamic-allocation compute (Sec.
IV-D) for the *linear* ops: capacity-mode SPLS packs critical token rows
to a static capacity ``C`` and computes the QKV / FFN matmuls only on
those rows.  Done naively in XLA that is two passes over HBM -- gather a
``(C, D)`` copy of the rows, then matmul it -- so this kernel fuses the
gather into the matmul's DMA schedule, the same move ``paged_decode``
makes for the block table:

* the packed row indices (``perm``) ride in as a **scalar-prefetch
  operand**;
* each grid step's row panel is brought into VMEM by **per-row async
  copies** resolved against ``perm`` (the gather happens in the DMA
  schedule; no ``(C, D)`` gathered copy ever lands in HBM), pipelined
  two-deep over a pair of DMA semaphores so row ``r + 1``'s copy is in
  flight while row ``r``'s is awaited;
* the MXU consumes the panel directly (K-slices of the VMEM panel), and
  the output tile accumulates across K steps exactly like
  ``hlog_qmatmul``.

The leader-scatter that recovers full-length outputs
(``out[row] = packed[src_slot[row]]``) is the same pattern with the
index on the *input* BlockSpec: :func:`gather_rows_kernel` resolves each
output row's source slot in the index map, so the scatter is also pure
DMA scheduling.  :func:`gathered_matmul` chains both when ``src_slot``
is given -- gather -> matmul -> leader-scatter in one call.

Numerics: with ``bk=None`` (the default) the whole contraction runs in
one MXU dot per tile, which keeps the result **bitwise identical** to
the XLA ``x[perm] @ w`` oracle (row/column subsets of an XLA dot are
bitwise stable; K-blocked accumulation is not -- callers that set ``bk``
trade that equality for a smaller VMEM footprint).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gathered_matmul", "gather_rows_kernel"]


def _gmm_kernel(perm_ref, x_hbm, w_ref, o_ref, xs, sem, *, bm, bk,
                double_buffer):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((j == 0) & (k == 0))
    def _gather():
        # per-row DMA gather of this tile's source rows into the VMEM
        # panel: the row index comes from the scalar-prefetch operand, so
        # the gather is part of the DMA schedule (cf. paged_decode's
        # block-table index maps, which gather at page granularity).
        # Double-buffered: row r+1's copy is issued before row r is
        # awaited, so at steady state one DMA is always in flight behind
        # the one being waited on (start/wait alternate between the two
        # DMA semaphores; each row lands directly in its own panel slot,
        # so only the semaphores rotate -- no staging copy).  Bitwise
        # identical to the serialized gather: destinations are disjoint
        # and the panel is fully awaited before the MXU reads it.
        def dma(r, slot):
            src = perm_ref[i * bm + r]
            return pltpu.make_async_copy(x_hbm.at[src], xs.at[r],
                                         sem.at[slot])

        if double_buffer:
            dma(0, 0).start()

            def body(r, carry):
                @pl.when(r + 1 < bm)
                def _start_next():
                    dma(r + 1, (r + 1) % 2).start()

                dma(r, r % 2).wait()
                return carry

            jax.lax.fori_loop(0, bm, body, 0)
        else:
            # serialized baseline (bench_kernels times it against the
            # buffered schedule): each row's copy fully completes before
            # the next is issued, so no DMA is ever in flight behind a
            # wait -- same destinations, bitwise-identical panel
            def body_serial(r, carry):
                d = dma(r, 0)
                d.start()
                d.wait()
                return carry

            jax.lax.fori_loop(0, bm, body_serial, 0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xt = xs[:, pl.ds(k * bk, bk)]
    o_ref[...] += jnp.dot(xt, w_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret",
                                    "double_buffer"))
def _gathered_matmul_padded(x: jax.Array, w: jax.Array, perm: jax.Array,
                            bm: int, bn: int, bk: int,
                            interpret: bool,
                            double_buffer: bool = True) -> jax.Array:
    C = perm.shape[0]
    _, D = x.shape
    _, F = w.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C // bm, F // bn, D // bk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),          # x stays in HBM
            pl.BlockSpec((bk, bn), lambda i, j, k, perm: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, perm: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, D), jnp.float32),              # gathered panel
            pltpu.SemaphoreType.DMA((2,)),      # double-buffered row copies
        ],
    )
    return pl.pallas_call(
        functools.partial(_gmm_kernel, bm=bm, bk=bk,
                          double_buffer=double_buffer),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, F), jnp.float32),
        interpret=interpret,
    )(perm, x, w)


def gathered_matmul(x: jax.Array, w: jax.Array, perm: jax.Array,
                    src_slot: Optional[jax.Array] = None,
                    bm: int = 128, bn: int = 128, bk: Optional[int] = None,
                    interpret: bool = True,
                    double_buffer: bool = True) -> jax.Array:
    """``x[perm] @ w`` with the gather fused into the matmul DMA schedule.

    x: (L, D) source rows; w: (D, F); perm: (C,) int32 packed row indices
    (may repeat; out-of-pack slots typically carry harmless filler rows).
    Returns (C, F) float32 -- or, with ``src_slot`` (M,) given, the
    leader-scattered (M, F) ``out[r] = (x[perm] @ w)[src_slot[r]]``
    (:func:`gather_rows_kernel` as the epilogue, still no XLA gather).

    Ragged C / F are padded internally (padded perm slots gather row 0,
    computed wastefully and sliced off -- the same discipline as the
    capacity pack).  ``bk=None`` runs the whole contraction per tile:
    bitwise equal to the XLA oracle; see module docstring.

    ``double_buffer=False`` serializes the row gather (start+wait per
    row, no overlap) -- bitwise identical, kept as the timing baseline
    that isolates what the two-semaphore pipeline buys
    (``benchmarks/bench_kernels.py`` times both; the dispatch carries a
    ``jax.profiler.TraceAnnotation`` so on-TPU profiles name the
    variant).
    """
    L, D = x.shape
    D2, F = w.shape
    assert D == D2, (x.shape, w.shape)
    C = perm.shape[0]
    bm = min(bm, C)
    bn = min(bn, F)
    bk = D if bk is None else min(bk, D)
    assert D % bk == 0, f"contraction {D} not tileable by bk={bk}"
    pc = (-C) % bm
    if pc:
        perm = jnp.pad(perm, (0, pc))
    pf = (-F) % bn
    if pf:
        w = jnp.pad(w, ((0, 0), (0, pf)))
    # named profiler annotation: on-TPU traces (and Perfetto exports of
    # jax.profiler captures) attribute the dispatch to the exact gather
    # schedule being measured
    variant = "buffered" if double_buffer else "serialized"
    with jax.profiler.TraceAnnotation(f"gathered_matmul/{variant}"):
        out = _gathered_matmul_padded(x.astype(jnp.float32),
                                      w.astype(jnp.float32),
                                      perm.astype(jnp.int32),
                                      bm, bn, bk, interpret,
                                      double_buffer=double_buffer)
    out = out[:C, :F]
    if src_slot is not None:
        out = gather_rows_kernel(out, src_slot, interpret=interpret)
    return out


def _gather_kernel(idx_ref, src_ref, o_ref):
    o_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows_kernel(src: jax.Array, idx: jax.Array,
                       interpret: bool = True) -> jax.Array:
    """``out[i] = src[idx[i]]`` -- the leader-scatter as pure DMA.

    src: (C, F); idx: (M,) int32 source row per output row.  The index
    rides in as a scalar-prefetch operand and each output row's source is
    resolved by the input BlockSpec index map, so the whole scatter is
    realised in the DMA schedule (no gathered intermediate, no XLA
    gather op) -- the row-granular version of ``paged_decode``'s
    block-table lookup.
    """
    C, F = src.shape
    M = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[pl.BlockSpec((1, F), lambda i, idx: (idx[i], 0))],
        out_specs=pl.BlockSpec((1, F), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, F), src.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), src)
