"""Pallas TPU kernels for the SPLS hot spots (+ pure-jnp oracles in ref.py).

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU in interpret mode against ref.py.
"""

from .flash_decode import flash_decode
from .gathered_matmul import gather_rows_kernel, gathered_matmul
from .paged_decode import paged_flash_decode
from .ops import (attention, flash_attention, hlog_qmatmul,
                  local_similarity_dist, predict_matmul, window_distances)
