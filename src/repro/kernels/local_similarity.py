"""Pallas TPU kernel: windowed pairwise L1 distances on the SPA.

The similarity unit of the accelerator compares the ``w`` rows of each local
window with L1 distance (Sec. III-B), costing L^2 (w-1) add/subs.  On TPU
the natural mapping is a reduction kernel: for each (batch*head, window) the
``w x Lk`` row tile streams through VMEM in ``bk`` column chunks and the
``w x w`` distance matrix accumulates in the revisited output block.

Grid: (B*H, L/w, Lk/bk), column chunks innermost.  VMEM per step is
``w * bk`` input floats plus the ``w*w`` accumulator -- tiny, so ``bk`` can
be large (2048 default) to amortise grid overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["local_similarity_dist"]


def _kernel(spa_ref, o_ref, *, w):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = spa_ref[0].astype(jnp.float32)            # (w, bk)
    d = jnp.abs(x[:, None, :] - x[None, :, :]).sum(-1)
    o_ref[0] += d


@functools.partial(jax.jit, static_argnames=("w", "bk", "interpret"))
def local_similarity_dist(spa: jax.Array, w: int = 8, bk: int = 2048,
                          interpret: bool = True) -> jax.Array:
    """spa: (B, H, L, Lk) with L % w == 0 -> (B, H, L//w, w, w) L1 dists."""
    B, H, L, Lk = spa.shape
    assert L % w == 0, (L, w)
    nw = L // w
    bk = min(bk, Lk)
    assert Lk % bk == 0
    xf = spa.reshape(B * H * nw, w, Lk)

    out = pl.pallas_call(
        functools.partial(_kernel, w=w),
        grid=(B * H * nw, 1, Lk // bk),
        in_specs=[pl.BlockSpec((1, w, bk), lambda b, i, j: (b, 0, j))],
        out_specs=pl.BlockSpec((1, w, w), lambda b, i, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H * nw, w, w), jnp.float32),
        interpret=interpret,
    )(xf)
    return out.reshape(B, H, nw, w, w)
