"""Energy/area model of ESACT (Tables II-IV).

Component area/power are the paper's synthesis numbers (TSMC 28 nm,
500 MHz).  Effective throughput counts dense-equivalent ops (the accelerator
convention: skipped work counts as delivered), so energy efficiency rises
with the measured sparsity -- reproducing the 3.27 TOPS/W end-to-end figure
and the SpAtten/Sanger comparison of Table IV.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from .cycles import ESACTConfig, stage_cycles

__all__ = ["ESACT_AREA_POWER", "BASELINES", "energy_efficiency",
           "attention_level_comparison"]

# Table II (total 5.09 mm^2, 792.12 mW)
ESACT_AREA_POWER: Dict[str, Dict[str, float]] = {
    "pe_array": {"area_mm2": 1.85, "power_mw": 324.14},
    "sparsity_prediction": {"area_mm2": 0.23, "power_mw": 57.43},
    "sram": {"area_mm2": 1.60, "power_mw": 317.84},
    "functional": {"area_mm2": 1.41, "power_mw": 92.71},
}

# Table IV, normalized to 28 nm by the paper
BASELINES: Dict[str, Dict[str, float]] = {
    "spatten": {"energy_eff_gops_w": 2261.0, "area_eff_gops_mm2": 677.0,
                "accuracy_loss": 0.007},
    "sanger": {"energy_eff_gops_w": 2958.0, "area_eff_gops_mm2": 1025.0,
               "accuracy_loss": 0.001},
}


def total_power_w() -> float:
    return sum(c["power_mw"] for c in ESACT_AREA_POWER.values()) / 1e3


def total_area_mm2() -> float:
    return sum(c["area_mm2"] for c in ESACT_AREA_POWER.values())


def energy_efficiency(L: int, D: int, H: int, d_ff: int,
                      reductions: Dict[str, float],
                      cfg: ESACTConfig = ESACTConfig()) -> Dict[str, float]:
    """End-to-end TOPS/W at the measured sparsity.

    Dense-equivalent ops per layer = 2 * total dense MACs; time from the
    cycle model with all three hardware features on.
    """
    dense_macs = (4.0 * L * D * D + 2.0 * L * L * D + 2.0 * L * D * d_ff)
    cyc = stage_cycles(cfg, L, D, H, d_ff, reductions, progressive=True,
                       dynamic=True)["total"]
    t = cyc / cfg.freq_hz
    ops = 2.0 * dense_macs
    tops = ops / t / 1e12
    return {
        "effective_tops": tops,
        "power_w": total_power_w(),
        "tops_per_w": tops / total_power_w(),
        "area_mm2": total_area_mm2(),
        "gops_per_mm2": ops / t / 1e9 / total_area_mm2(),
    }


def attention_level_comparison(L: int, D: int, H: int,
                               attn_reduction: float,
                               cfg: ESACTConfig = ESACTConfig()
                               ) -> Dict[str, float]:
    """Table IV: attention-only energy efficiency vs SpAtten / Sanger.

    Attention power = PE array + prediction + a proportional share of SRAM
    and functional logic (the paper attributes the full chip to the
    attention measurement).
    """
    dense_macs = 2.0 * L * L * D
    cyc = stage_cycles(cfg, L, D, H, 1, {"attention": attn_reduction,
                                         "qkv": 0.0, "ffn": 0.0},
                       progressive=True, dynamic=True)["attention"] + \
        stage_cycles(cfg, L, D, H, 1, {"attention": attn_reduction,
                                       "qkv": 0.0, "ffn": 0.0},
                     progressive=True, dynamic=True)["prediction"]
    t = cyc / cfg.freq_hz
    gops = 2.0 * dense_macs / t / 1e9
    eff = gops / total_power_w()
    return {
        "attention_gops": gops,
        "energy_eff_gops_w": eff,
        "vs_spatten": eff / BASELINES["spatten"]["energy_eff_gops_w"],
        "vs_sanger": eff / BASELINES["sanger"]["energy_eff_gops_w"],
        "area_eff_gops_mm2": gops / total_area_mm2(),
    }
