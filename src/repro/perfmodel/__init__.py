"""Analytic performance + energy models of the ESACT accelerator."""

from .cycles import ESACTConfig, speedup_breakdown, stage_cycles
from .energy import (BASELINES, ESACT_AREA_POWER, attention_level_comparison,
                     energy_efficiency)
