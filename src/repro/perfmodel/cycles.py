"""Cycle-level performance model of the ESACT accelerator (Sec. V-C).

The paper builds a Verilator-calibrated cycle simulator; without RTL we
reproduce its *structure*: per-stage cycle counts for a weight-stationary
16x64 PE array at 500 MHz, scaled by the sparsity ratios the SPLS run
actually measured, with the progressive-generation overlap and the
dynamic-allocation utilization recovery applied as in Sec. IV-C/D.

The model reports the same speedup decomposition as Fig. 20:
  dense ASIC -> +SPLS sparsity -> +progressive generation -> +dynamic
  allocation, multiplying to the end-to-end speedup.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

__all__ = ["ESACTConfig", "stage_cycles", "speedup_breakdown"]


@dataclasses.dataclass(frozen=True)
class ESACTConfig:
    pe_rows: int = 16
    pe_cols: int = 64
    freq_hz: float = 500e6
    # utilization of the PE array on irregular similarity-sparse work before
    # and after the dynamic allocation strategy (Sec. V-C reports 81.57% at
    # k=0.1; dynamic matching shortens the critical path)
    util_before_dynamic: float = 0.8157
    util_after_dynamic: float = 0.849   # calibrated: paper's 1.04x dynamic gain
    # fraction of prediction latency hidden by progressive generation
    progressive_overlap: float = 0.85

    @property
    def macs_per_cycle(self) -> int:
        return self.pe_rows * self.pe_cols


def _stage_macs(L: int, D: int, H: int, d_ff: int) -> Dict[str, float]:
    """Dense per-layer MAC counts for the three sparsifiable stages."""
    return {
        "qkv": 4.0 * L * D * D,                 # Wq, Wk, Wv, Wo
        "attention": 2.0 * L * L * D,           # QK^T + AV over all heads
        "ffn": 2.0 * L * D * d_ff,
    }


def _prediction_macs(L: int, D: int, H: int) -> float:
    """SPLS prediction work (HLog matmuls are additions on the ASIC; we
    charge them at 0.5 MAC-equivalents per the SJA's adder datapath)."""
    qk_pred = 2.0 * L * D * D
    attn_pred = L * L * D / max(H, 1)  # per-head Dh contraction
    similarity = L * L  # L1 adds on SPA
    return 0.5 * (qk_pred + attn_pred) + similarity


def stage_cycles(cfg: ESACTConfig, L: int, D: int, H: int, d_ff: int,
                 reductions: Dict[str, float] | None = None,
                 progressive: bool = False, dynamic: bool = False
                 ) -> Dict[str, float]:
    """Per-stage cycles for one transformer layer.

    ``reductions``: fractional computation removed per stage, e.g. the
    measured SPLS numbers {"qkv": .65, "attention": .94, "ffn": .50};
    None = dense.
    """
    macs = _stage_macs(L, D, H, d_ff)
    red = reductions or {"qkv": 0.0, "attention": 0.0, "ffn": 0.0}
    util = cfg.util_after_dynamic if dynamic else cfg.util_before_dynamic
    out: Dict[str, float] = {}
    for stage, m in macs.items():
        kept = m * (1.0 - red.get(stage, 0.0))
        u = util if red.get(stage, 0.0) > 0 else 1.0  # dense runs at 100%
        out[stage] = kept / (cfg.macs_per_cycle * u)
    if reductions is not None:
        pred = _prediction_macs(L, D, H) / cfg.macs_per_cycle
        if progressive:
            pred *= (1.0 - cfg.progressive_overlap)
        out["prediction"] = pred
    else:
        out["prediction"] = 0.0
    out["total"] = sum(out.values())
    return out


def speedup_breakdown(L: int, D: int, H: int, d_ff: int,
                      reductions: Dict[str, float],
                      cfg: ESACTConfig = ESACTConfig()) -> Dict[str, float]:
    """Fig. 20-style multiplicative decomposition over one layer."""
    dense = stage_cycles(cfg, L, D, H, d_ff, None)["total"]
    spls = stage_cycles(cfg, L, D, H, d_ff, reductions)["total"]
    prog = stage_cycles(cfg, L, D, H, d_ff, reductions,
                        progressive=True)["total"]
    dyn = stage_cycles(cfg, L, D, H, d_ff, reductions, progressive=True,
                       dynamic=True)["total"]
    return {
        "spls_speedup": dense / spls,
        "progressive_speedup": spls / prog,
        "dynamic_speedup": prog / dyn,
        "end_to_end_speedup": dense / dyn,
        "dense_cycles": dense,
        "final_cycles": dyn,
        "tokens_per_s": L * cfg.freq_hz / dyn,
    }
