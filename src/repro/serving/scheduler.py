"""Continuous-batching scheduler: chunked prefill, admission, preemption.

Pure host-side policy over the :class:`~repro.serving.pager.PagePool`; the
engine executes whatever the scheduler decides.  The dataflow per tick:

1. **admit** -- waiting requests move into free batch slots while the pool
   can cover their first unit of work (admission control is keyed on free
   pages, not slots alone).
2. **prefill** -- at most ``max_prefills_per_tick`` prefill-phase sequences
   advance by one prompt chunk.  Decode never waits for a whole prompt:
   a 10k-token prefill is sliced into ``prefill_chunk``-token pieces
   interleaved with decode ticks (no head-of-line blocking).  With SPLS
   the chunk also carries its slice of the progressive sparsity plan; the
   page-prune vote finalizes with the last chunk, after which the engine
   compacts kept columns and the freed pages come back here.
3. **decode** -- every decode-phase sequence produces one token.  Crossing
   a page boundary allocates a page on demand; when the pool is dry the
   youngest other sequence is **preempted by page eviction**: its pages go
   back to the free list and the request re-queues at the *front* of the
   waiting line with its generated tokens folded into the prompt
   (recompute-style preemption -- greedy decoding reproduces the identical
   continuation after re-prefill, *unless* SPLS page pruning is on: the
   resume re-plans over the extended sequence and may prune a different
   column set, so pruned outputs can depend on pool pressure).

Sequences whose worst-case footprint (prompt + max_new tokens) exceeds the
pool are rejected at submit: they could never run.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import List, Optional

from repro.observability import CounterDictView, Telemetry
from repro.sparse_compute.accounting import saved_pct

from .pager import PagePool

__all__ = ["SchedulerConfig", "SeqState", "Scheduler"]

_STAT_KEYS = ("admitted", "preemptions", "retired", "prefill_chunks",
              "aborted")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    n_slots: int = 4
    prefill_chunk: int = 64        # prompt tokens advanced per prefill tick
    max_prefills_per_tick: int = 1  # chunked-prefill fairness knob
    watermark: int = 0              # free pages held back at admission
    # post-prune estimate smoothing (prune-aware page accounting) and the
    # abort guard for optimistically admitted requests that can never fit
    prune_ema: float = 0.5
    max_solo_preemptions: int = 3


@dataclasses.dataclass
class SeqState:
    """One admitted sequence (batch row)."""

    req: object                    # the engine's Request
    base_prompt: List[int]         # the request's original prompt tokens
    tokens: List[int]              # prefill target: base (+ regenerated
    #                                output when resuming after preemption)
    budget: int                    # new tokens still to produce
    slot: int
    admit_seq: int                 # admission order (preemption victim key)
    pages: List[int] = dataclasses.field(default_factory=list)
    kv_len: int = 0                # page slots written
    cur_pos: int = 0               # next original position
    prefilled: int = 0             # prompt tokens processed
    head_votes: Optional[object] = None  # (H, S) bool cross-chunk SPLS
    #                                      column-keep accumulator
    live: Optional[object] = None  # (S,) bool horizon-vote liveness (None
    #                                until the first chunk under a finite
    #                                vote_horizon; see core.planner)

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)

    @property
    def phase(self) -> str:
        return "prefill" if self.prefilled < self.prompt_len else "decode"


class Scheduler:
    def __init__(self, cfg: SchedulerConfig, pool: PagePool,
                 max_len: int, chunkable: bool = True,
                 prune_aware: bool = False, chunk_all: bool = False,
                 telemetry: Optional[Telemetry] = None):
        self.cfg = cfg
        self.pool = pool
        # the engine threads its telemetry in; a bare scheduler gets a
        # disabled one (back-compat counters still work -- they live on
        # the always-on core registry, not behind the knob)
        self.tel = telemetry if telemetry is not None \
            else Telemetry(enabled=False)
        self.max_len = max_len
        # chunked prefill needs causal cross-chunk attention; the engine
        # disables it for non-causal models (SPLS configs now stream their
        # plan chunk by chunk instead of bypassing chunking)
        self.chunkable = chunkable
        # route *every* prefill through the chunk path, including whole
        # prompts (<= one chunk): the packed-compute engine sets this so
        # short prompts get the same token-compacted QKV/FFN execution as
        # long ones instead of silently running the dense full-prefill
        # path (outputs are identical either way -- chunked-vs-full parity
        # is test-pinned -- only the executed FLOPs differ)
        self.chunk_all = chunk_all and chunkable
        # SPLS page pruning: track observed kept/prompt ratios (EMA) so
        # page-need accounting can use a post-prune estimate instead of
        # assuming dense footprints; conservative (dense) fallback until
        # the first observation
        self.prune_aware = prune_aware
        self.prune_ratio: Optional[float] = None
        self.waiting: deque = deque()   # (req, base_prompt, tokens, budget)
        self.slots: List[Optional[SeqState]] = [None] * cfg.n_slots
        self.aborted: List = []         # optimistically admitted, never fit
        self._solo_preempts: dict = {}  # rid -> self-preemption count
        self._admit_seq = 0
        # typed Counter instruments on the telemetry's always-on core
        # registry, behind a dict-shaped live view so legacy
        # `stats["k"] += 1` call sites and test assertions keep working
        self.stats = CounterDictView(self.tel.core, "sched/", _STAT_KEYS)
        # lifetime FLOPs accounting: [dense-equivalent, executed] per
        # component, accumulated over every prefill the engine runs --
        # the measured realization of the paper's Fig. 15 breakdown on
        # the serving path (fed by sparse_compute.accounting.chunk_flops)
        self.flops = {c: [0.0, 0.0] for c in ("qkv", "attn", "ffn")}

    # ------------------------------------------------------------------
    def note_flops(self, comp: dict) -> None:
        """Accumulate one prefill step's (dense, executed) FLOPs per
        component (``{"qkv": (dense, executed), ...}``).  Components not
        seen before (e.g. the standalone ``kv`` share of the
        horizon-finalized K/V packing) are added on first observation."""
        for c, (dense, executed) in comp.items():
            acc = self.flops.setdefault(c, [0.0, 0.0])
            acc[0] += dense
            acc[1] += executed

    def flops_saved_pct(self) -> dict:
        """Lifetime percent of dense-equivalent FLOPs *not* executed,
        per component (0.0 before any prefill ran)."""
        return saved_pct(self.flops)

    def note_prune(self, prompt_len: int, kept: int) -> None:
        """Record an observed post-prune keep ratio (engine calls this
        after every pruned prefill); feeds the admission estimate."""
        if prompt_len <= 0:
            return
        r = kept / prompt_len
        self.prune_ratio = (r if self.prune_ratio is None else
                            (1 - self.cfg.prune_ema) * self.prune_ratio
                            + self.cfg.prune_ema * r)

    def lifetime_pages(self, lp: int, budget: int) -> int:
        """Worst-case pages a request holds at once over its lifetime.

        Dense accounting (``pages_for(lp + budget)``) is the conservative
        fallback.  With pruning observed, the post-prune estimate applies:
        after prefill the sequence holds ``~ratio * lp`` kept slots plus
        its decode growth, while the prefill-time peak is the dense prompt
        (chunked prefill materializes every column until the vote
        finalizes) or the kept count (full prefill allocates post-prune).
        Underestimates are survivable: a request that turns out not to fit
        is aborted by the solo-preemption guard instead of livelocking.
        """
        dense = self.pool.pages_for(min(lp + budget, self.max_len))
        if not self.prune_aware or self.prune_ratio is None:
            return dense
        kept = math.ceil(self.prune_ratio * lp)
        prefill_peak = self.pool.pages_for(
            lp if self.use_chunks(lp) else kept)
        post = self.pool.pages_for(min(kept + budget, self.max_len))
        return min(dense, max(prefill_peak, post))

    def submit(self, req, prompt_tokens: List[int], budget: int) -> None:
        lp = len(prompt_tokens)
        first = (min(lp, self.cfg.prefill_chunk) if self.use_chunks(lp)
                 else lp)
        # both the lifetime footprint and the admission need (first unit of
        # work + watermark) must fit, else the request could never run
        worst = max(self.lifetime_pages(lp, budget),
                    self.pool.pages_for(first) + self.cfg.watermark)
        if worst > self.pool.capacity:
            raise ValueError(
                f"request {req.rid}: needs up to {worst} pages but the pool "
                f"only has {self.pool.capacity}")
        self.waiting.append((req, prompt_tokens, list(prompt_tokens), budget))

    def active(self) -> List[SeqState]:
        return [s for s in self.slots if s is not None]

    def decode_ready(self) -> List[SeqState]:
        return [s for s in self.slots if s is not None
                and s.phase == "decode"]

    def idle(self) -> bool:
        return not self.waiting and not self.active()

    # ------------------------------------------------------------------
    def admit(self) -> List[SeqState]:
        """Fill free slots from the waiting queue while pages allow."""
        admitted = []
        for slot in range(self.cfg.n_slots):
            if self.slots[slot] is not None or not self.waiting:
                continue
            req, base, tokens, budget = self.waiting[0]
            first = (min(len(tokens), self.cfg.prefill_chunk)
                     if self.use_chunks(len(tokens)) else len(tokens))
            need = self.pool.pages_for(first) + self.cfg.watermark
            if need > self.pool.free_pages:
                break  # FIFO: don't let later requests starve the head
            self.waiting.popleft()
            st = SeqState(req=req, base_prompt=base, tokens=tokens,
                          budget=budget, slot=slot,
                          admit_seq=self._admit_seq)
            self._admit_seq += 1
            self.slots[slot] = st
            self.stats["admitted"] += 1
            self.tel.request_admitted(req.rid)
            admitted.append(st)
        return admitted

    def use_chunks(self, prompt_len: int) -> bool:
        return self.chunkable and (prompt_len > self.cfg.prefill_chunk
                                   or self.chunk_all)

    def plan_prefills(self) -> List[SeqState]:
        """Prefill-phase sequences to advance this tick, oldest first."""
        pending = sorted((s for s in self.slots
                          if s is not None and s.phase == "prefill"),
                         key=lambda s: s.admit_seq)
        return pending[:self.cfg.max_prefills_per_tick]

    # ------------------------------------------------------------------
    def grow_to(self, st: SeqState, n_slots_total: int) -> bool:
        """Ensure ``st`` owns pages covering ``n_slots_total`` written
        slots, preempting younger sequences when the pool runs dry.
        Returns False if ``st`` itself had to be preempted (last resort:
        no other sequence holds pages to evict)."""
        while True:
            need = self.pool.pages_for(n_slots_total) - len(st.pages)
            if need <= 0:
                self._solo_preempts.pop(st.req.rid, None)
                return True
            got = self.pool.alloc(need)
            if got is not None:
                st.pages.extend(got)
                self._solo_preempts.pop(st.req.rid, None)
                return True
            victim = self._pick_victim(st)
            if victim is None:
                # nobody else to evict.  Under conservative (dense)
                # admission this is transient; under the optimistic
                # post-prune estimate a request may genuinely never fit --
                # re-prefilling it forever would livelock the engine, so
                # after max_solo_preemptions it is aborted instead (the
                # engine retires it with whatever it generated).
                rid = st.req.rid
                n = self._solo_preempts.get(rid, 0) + 1
                self._solo_preempts[rid] = n
                if n > self.cfg.max_solo_preemptions:
                    self.pool.free(st.pages)
                    st.pages = []
                    self.slots[st.slot] = None
                    self.aborted.append(st.req)
                    self.stats["aborted"] += 1
                    del self._solo_preempts[rid]  # rid may be resubmitted
                    return False
                self.preempt(st)
                return False
            self.preempt(victim)

    def _pick_victim(self, requester: SeqState) -> Optional[SeqState]:
        others = [s for s in self.slots
                  if s is not None and s is not requester and s.pages]
        if not others:
            return None
        return max(others, key=lambda s: s.admit_seq)  # youngest first

    def preempt(self, st: SeqState) -> None:
        """Evict ``st``'s pages and requeue it at the front of the line
        (recompute-style): tokens generated so far fold into the prefill
        target, so greedy decoding resumes the identical continuation
        (exactly -- unless SPLS page pruning re-plans the longer sequence
        differently; see the module docstring)."""
        self.pool.free(st.pages)
        st.pages = []
        self.slots[st.slot] = None
        tokens = list(st.base_prompt) + list(st.req.output)
        budget = st.req.max_new_tokens - len(st.req.output)
        self.waiting.appendleft((st.req, st.base_prompt, tokens, budget))
        self.stats["preemptions"] += 1
        self.tel.request_preempted(st.req.rid)

    def retire(self, st: SeqState) -> None:
        self.pool.free(st.pages)
        st.pages = []
        self.slots[st.slot] = None
        self._solo_preempts.pop(st.req.rid, None)
        self.stats["retired"] += 1
