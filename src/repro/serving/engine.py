"""Serving engines: dense fixed-slot and block-pool paged.

:class:`ServingEngine` is the original continuous-batching engine -- a
dense ``n_slots x max_len`` KV cache, whole-prompt prefill into a free
slot, one batched decode per tick.  It remains the baseline (and the
parity oracle) for the paged engine.

:class:`PagedServingEngine` is the production-shaped path: KV lives in a
shared :class:`~repro.serving.pager.PagePool`, requests hold block tables
instead of cache rows, prompts longer than a chunk prefill incrementally
*between* decode ticks (no head-of-line blocking), admission is keyed on
free pages, and a dry pool preempts the youngest sequence by page
eviction.  With SPLS enabled, prefill prunes dead KV columns out of the
pool entirely (``spls_token_keep``), so the paper's sparsity buys
admission capacity, not just skipped math.

Both engines share :class:`Request`/:class:`ServeConfig` and the sampling
path: ``greedy=True`` (default) takes the argmax; ``greedy=False`` samples
with ``temperature`` through a PRNG key threaded from ``seed``.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from collections import deque
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import decode_step, init_cache, prefill
from repro.models.attn_backend import AUTO
from repro.observability import Telemetry, tree_bytes
from repro.sparse_compute import (CapacityController, chunk_flops, is_packed,
                                  resolve_compute_backend)

from .pager import (NULL_PAGE, PagePool, init_paged_cache, init_pos_pages,
                    init_pred_cache, keep_from_votes, spls_token_votes)
from .paged_model import (compact_slots, paged_decode_step,
                          paged_prefill_chunk, paged_prefill_chunk_spls,
                          scatter_prefill)
from .scheduler import Scheduler, SchedulerConfig, SeqState

__all__ = ["Request", "ServeConfig", "ServingEngine", "PagedServingEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (Lp,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 4
    max_len: int = 256
    # sampling: greedy argmax by default; greedy=False samples with
    # `temperature` through a PRNG key threaded from `seed`
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # attention backend override for this engine (None = cfg/auto); see
    # repro.models.attn_backend -- prefill resolves the forward side
    # (e.g. "pallas_flash"), ticks resolve the decode side (the paged
    # engine resolves the *paged* decode side).
    attn_backend: Optional[str] = None
    # paged-engine knobs (ignored by the dense engine)
    page_size: int = 16
    n_pages: Optional[int] = None   # None -> n_slots * pages(max_len) + 1
    prefill_chunk: int = 64
    max_prefills_per_tick: int = 1
    watermark: int = 0
    spls_page_prune: bool = True    # prune dead KV columns out of the pool
    spls_prune_vote: float = 0.5    # head-vote fraction a column must win
    # round a misaligned prefill_chunk up to the next multiple of
    # spls.window (one-time warning) instead of raising
    auto_align_chunk: bool = False
    # end-to-end sparse compute on the SPLS chunked-prefill path
    # (repro.sparse_compute): None -> cfg.compute_backend ("dense" keeps
    # today's simulation-mode execution); "packed_xla"/"packed_pallas"
    # compute only critical rows at bucketed static capacities
    compute_backend: Optional[str] = None
    # static capacity bucket set for the packed path (None -> quarter
    # steps of prefill_chunk); the margin scales the EMA'd critical-row
    # estimate before bucket selection (sparse_compute.CapacityController)
    capacity_buckets: Optional[Tuple[int, ...]] = None
    capacity_margin: float = 1.25
    # horizon-finalized column votes (repro.core.planner): None keeps
    # the end-of-prefill prune vote bit-for-bit; a finite horizon h >= 1
    # finalizes a column as pruned once it has been votable for h
    # consecutive chunks while still below the cross-head agreement
    # threshold (ceil(spls_prune_vote * H) heads -- the same bar the
    # end-of-prefill vote applies, evaluated early; bounded divergence
    # for K/V savings).  h == 1 with a packed compute backend
    # additionally packs the K/V *projection* to the surviving columns
    # -- the chunk's own plan votes land before formal QKV generation,
    # so pruned columns are never projected at all.
    vote_horizon: Optional[int] = None
    # serving telemetry (repro.observability): per-request lifecycle
    # spans, TTFT/TPOT histograms, SPLS sparsity instruments, and the
    # BENCH_serving.json report.  Default-on; False swaps in no-op sinks
    # that record nothing (the back-compat `stats` counters stay live
    # either way -- they are engine state, not diagnostics).  All
    # instruments are host-side with injected monotonic timestamps;
    # greedy outputs are bit-for-bit identical on and off.
    telemetry: bool = True


def _backend_for_site(name: Optional[str], *, decode: bool,
                      paged: bool = False) -> Optional[str]:
    """Route a ServeConfig.attn_backend name to one engine site.

    The single config field intentionally drives every site an engine
    has; a site of a different kind resolves ``"auto"``.  Doing the kind
    split *here* keeps the registry's kind-mismatch warning reserved for
    genuine configuration errors instead of firing on the engines' own
    documented fall-through (and keeps ``STRICT_BACKEND_KIND`` usable
    with the engines)."""
    if name is None or name == AUTO:
        return name
    from repro.models import available_backends

    return (name if name in available_backends(decode=decode, paged=paged)
            else AUTO)


def _sample_tokens(key: Optional[jax.Array], logits: jax.Array,
                   greedy: bool, temperature: float) -> jax.Array:
    """logits (..., V) -> (...,) int32 token ids."""
    if greedy or temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


class _SamplerMixin:
    def _init_sampler(self, scfg: ServeConfig) -> None:
        self.scfg = scfg
        self._key = jax.random.PRNGKey(scfg.seed)

    def _pick(self, logits: jax.Array) -> jax.Array:
        key = None
        if not self.scfg.greedy:
            self._key, key = jax.random.split(self._key)
        return _sample_tokens(key, logits, self.scfg.greedy,
                              self.scfg.temperature)


# ---------------------------------------------------------------------------
# dense fixed-slot engine (the baseline / parity oracle)
# ---------------------------------------------------------------------------

class ServingEngine(_SamplerMixin):
    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig):
        assert cfg.input_mode == "tokens", "engine serves token models"
        # the dense engine has no packed-compute path (it is the
        # simulation-mode parity oracle); surface a requested packed
        # backend loudly instead of silently measuring dense compute
        if is_packed(resolve_compute_backend(
                scfg.compute_backend if scfg.compute_backend is not None
                else cfg.compute_backend, sparse=cfg.spls.enabled)):
            warnings.warn(
                "ServingEngine (dense fixed-slot) executes dense compute "
                "only; the configured packed compute_backend applies to "
                "PagedServingEngine's chunked SPLS prefill and is ignored "
                "here", RuntimeWarning, stacklevel=2)
        if scfg.vote_horizon is not None:
            warnings.warn(
                "ServingEngine prefills whole prompts with the "
                "end-of-prefill prune vote; vote_horizon applies to "
                "PagedServingEngine's chunked SPLS prefill and is ignored "
                "here", RuntimeWarning, stacklevel=2)
        cfg_fwd, cfg_dec = cfg, cfg
        if scfg.attn_backend is not None:
            cfg_fwd = dataclasses.replace(cfg, attn_backend=_backend_for_site(
                scfg.attn_backend, decode=False))
            cfg_dec = dataclasses.replace(cfg, attn_backend=_backend_for_site(
                scfg.attn_backend, decode=True))
        self.cfg, self.params = cfg, params
        self._init_sampler(scfg)
        self.telemetry = Telemetry(enabled=scfg.telemetry)
        self.queue: deque = deque()
        self.slots: List[Optional[Request]] = [None] * scfg.n_slots
        self.pos = jnp.zeros((scfg.n_slots,), jnp.int32)
        self.tokens = jnp.zeros((scfg.n_slots, 1), jnp.int32)
        self.cache = init_cache(cfg, scfg.n_slots, scfg.max_len)
        self._retired: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg_dec, p, c, t, pos))
        # SPLS configs prefill with the progressive (streaming-
        # reproducible) plan builder so this engine stays the exact parity
        # oracle for the paged engine's chunked SPLS prefill
        plan_mode = "progressive" if cfg.spls.enabled else "auto"
        self._prefill = jax.jit(
            lambda p, toks: prefill(cfg_fwd, p, toks, max_len=scfg.max_len,
                                    plan_mode=plan_mode))

    # ------------------------------------------------------------------
    @property
    def stats(self) -> dict:
        """Minimal stats view (the paged engine carries the full set);
        dense compute executes everything, so savings are all zero."""
        return {"retired": len(self._retired),
                "compute_backend": "dense",
                "flops_saved_pct": {}}

    def submit(self, req: Request) -> None:
        self.telemetry.request_submitted(req.rid,
                                         int(req.prompt.shape[0]))
        self.queue.append(req)

    def _admit(self) -> None:
        """Move queued requests into free slots (prefill their prompt)."""
        for s in range(self.scfg.n_slots):
            if self.slots[s] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            self.telemetry.request_admitted(req.rid)
            lp = int(req.prompt.shape[0])
            self.telemetry.span_begin("full_prefill", rid=req.rid)
            logits, cache1 = self._prefill(self.params,
                                           req.prompt[None, :])
            # splice this row's prefilled cache into slot s
            self.cache = jax.tree.map(
                lambda full, one: full.at[:, s:s + 1].set(one),
                self.cache, cache1)
            nxt = int(self._pick(logits[0, -1]))
            req.output.append(nxt)
            self.telemetry.span_end("full_prefill", rid=req.rid)
            self.telemetry.first_token(req.rid)
            self.slots[s] = req
            self.pos = self.pos.at[s].set(lp)
            self.tokens = self.tokens.at[s, 0].set(nxt)

    def _retire(self) -> None:
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            hit_eos = req.eos_id is not None and req.eos_id in req.output
            if len(req.output) >= req.max_new_tokens or hit_eos or \
                    int(self.pos[s]) >= self.scfg.max_len - 1:
                req.done = True
                self.slots[s] = None
                self._retired.append(req)
                self.telemetry.request_retired(req.rid)

    def tick(self) -> int:
        """One engine iteration; returns number of active slots decoded."""
        self._admit()
        self._retire()  # a prefill-emitted token may already hit eos/budget
        active = [s for s, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        self.telemetry.span_begin("decode_tick",
                                  args={"n_active": len(active)})
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens, self.pos)
        nxt = self._pick(logits[:, 0])
        for s in active:
            tok = int(nxt[s])
            self.slots[s].output.append(tok)
        self.telemetry.span_end("decode_tick")
        self.telemetry.tokens_decoded(
            [self.slots[s].rid for s in active])
        self.pos = self.pos + jnp.asarray(
            [1 if self.slots[s] is not None else 0
             for s in range(self.scfg.n_slots)], jnp.int32)
        self.tokens = nxt[:, None]
        self._retire()
        return len(active)

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        """Tick until queue and slots are empty; returns the requests that
        retired during this call, in retirement order."""
        start = len(self._retired)
        for _ in range(max_ticks):
            self.tick()
            if not self.queue and all(s is None for s in self.slots):
                break
        return self._retired[start:]


# ---------------------------------------------------------------------------
# paged engine
# ---------------------------------------------------------------------------

class PagedServingEngine(_SamplerMixin):
    """Continuous batching over the block-pool paged KV cache."""

    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig):
        assert cfg.input_mode == "tokens", "engine serves token models"
        assert all(b.mixer == "attn" for b in cfg.period), \
            "paged engine is attention-only (SSM state is O(1) per slot)"
        cfg_fwd, cfg_pgd = cfg, cfg
        if scfg.attn_backend is not None:
            cfg_fwd = dataclasses.replace(cfg, attn_backend=_backend_for_site(
                scfg.attn_backend, decode=False))
            cfg_pgd = dataclasses.replace(cfg, attn_backend=_backend_for_site(
                scfg.attn_backend, decode=True, paged=True))
        # chunked prefill needs causal cross-chunk attention.  SPLS no
        # longer disables it: the plan streams one window-aligned chunk at
        # a time (the paper's progressive generation scheme) and the
        # page-prune vote accumulates across chunks.
        chunkable = cfg.causal
        if cfg.spls.enabled and chunkable \
                and scfg.prefill_chunk % cfg.spls.window:
            if scfg.auto_align_chunk:
                aligned = -(-scfg.prefill_chunk // cfg.spls.window) \
                    * cfg.spls.window
                warnings.warn(
                    f"prefill_chunk ({scfg.prefill_chunk}) is not a "
                    f"multiple of the SPLS similarity window "
                    f"({cfg.spls.window}); auto_align_chunk rounded it up "
                    f"to {aligned}", RuntimeWarning, stacklevel=2)
                scfg = dataclasses.replace(scfg, prefill_chunk=aligned)
            else:
                raise ValueError(
                    f"prefill_chunk ({scfg.prefill_chunk}) must be a "
                    f"multiple of the SPLS similarity window "
                    f"({cfg.spls.window}): chunk boundaries must align "
                    f"with similarity windows for chunked prefill to "
                    f"reproduce the full-prefill plan (set "
                    f"ServeConfig.auto_align_chunk=True to round up)")
        self.cfg, self.params = cfg, params
        self._init_sampler(scfg)

        ps = scfg.page_size
        self.page_size = ps
        self.pages_per_seq = math.ceil(scfg.max_len / ps)
        n_pages = (scfg.n_pages if scfg.n_pages is not None
                   else scfg.n_slots * self.pages_per_seq + 1)
        self.pool = PagePool(n_pages, ps)
        self._prune = cfg.spls.enabled and scfg.spls_page_prune
        # end-to-end sparse compute (the SPLS chunked-prefill path):
        # "dense" keeps simulation-mode execution; packed backends compute
        # only critical rows at bucketed static capacities (one jit per
        # bucket pair) with leaders broadcasting to their followers
        self._compute = resolve_compute_backend(
            scfg.compute_backend if scfg.compute_backend is not None
            else cfg.compute_backend, sparse=cfg.spls.enabled)
        # horizon-finalized column votes (core.planner): a finite horizon
        # needs the streaming chunked path AND page pruning (the horizon
        # decision *is* a prune decision)
        self._horizon = scfg.vote_horizon
        # the horizon's early finalization applies the same cross-head
        # agreement bar as the end-of-prefill vote (keep_from_votes)
        self._vote_need = max(1, math.ceil(scfg.spls_prune_vote
                                           * cfg.n_heads))
        if self._horizon is not None:
            if self._horizon < 1:
                raise ValueError(
                    f"vote_horizon must be >= 1 chunks (or None for the "
                    f"end-of-prefill vote), got {self._horizon}")
            if not (cfg.spls.enabled and self._prune and chunkable):
                raise ValueError(
                    "vote_horizon requires SPLS (cfg.spls.enabled), page "
                    "pruning (ServeConfig.spls_page_prune) and a causal "
                    "model (chunked prefill): the horizon finalizes the "
                    "streaming prune vote early")
        cs = scfg.prefill_chunk
        if is_packed(self._compute):
            self._cap_q = CapacityController(
                cs, buckets=scfg.capacity_buckets,
                margin=scfg.capacity_margin)
            self._cap_f = CapacityController(
                cs, buckets=scfg.capacity_buckets,
                margin=scfg.capacity_margin)
            # K/V projection capacity: only meaningful at vote_horizon == 1
            # (the only horizon whose decision precedes K/V generation)
            self._cap_kv = (CapacityController(
                cs, buckets=scfg.capacity_buckets,
                margin=scfg.capacity_margin)
                if self._horizon == 1 else None)
        else:
            self._cap_q = self._cap_f = self._cap_kv = None
        self.telemetry = Telemetry(enabled=scfg.telemetry)
        self.sched = Scheduler(
            SchedulerConfig(n_slots=scfg.n_slots,
                            prefill_chunk=scfg.prefill_chunk,
                            max_prefills_per_tick=scfg.max_prefills_per_tick,
                            watermark=scfg.watermark),
            self.pool, scfg.max_len, chunkable=chunkable,
            prune_aware=self._prune,
            # packed compute: route whole prompts (<= one chunk) through
            # the chunk path too, so short prompts get token compaction
            # instead of silently running the dense full-prefill path
            chunk_all=is_packed(self._compute),
            telemetry=self.telemetry)

        self.cache = init_paged_cache(cfg, n_pages, ps)
        self.pos_pages = init_pos_pages(n_pages, ps)
        # the paged SPLS predictor cache is allocated lazily on the first
        # chunked SPLS prefill: full-prefill-only workloads (every prompt
        # <= prefill_chunk) never pay its pool memory
        self.pred_cache = None
        self._n_pages = n_pages
        self._retired: List[Request] = []
        # the old cache / pos_pages references die on reassignment every
        # tick, so donate them: decode scatters one token in place instead
        # of copying the whole page pool (donation is a no-op on CPU)
        self._decode = jax.jit(
            lambda p, c, pp, tb, kl, cp, t: paged_decode_step(
                cfg_pgd, p, c, pp, tb, kl, cp, t), donate_argnums=(1, 2))
        plan_mode = "progressive" if cfg.spls.enabled else "auto"
        self._prefill = jax.jit(
            lambda p, toks: prefill(cfg_fwd, p, toks, plan_mode=plan_mode))
        self._votes = jax.jit(
            lambda p, toks: spls_token_votes(cfg, p, toks))
        self._chunk = jax.jit(
            lambda p, c, pp, tb, start, toks, valid: paged_prefill_chunk(
                cfg, p, c, pp, tb, start, toks, valid),
            donate_argnums=(1, 2))
        # SPLS chunk step: one jit covers *all* prompt lengths (top-k
        # count, start, and valid ride in as traced scalars); under packed
        # compute, one jit per capacity-bucket pair (the controller keeps
        # the pair set small)
        self._chunk_spls_jits: dict = {}
        self._compact = jax.jit(
            lambda c, pp, tb, keep: compact_slots(c, pp, tb, keep),
            donate_argnums=(0, 1))
        # pool byte gauges (metadata only, no device sync); the predictor
        # cache gauge updates when its lazy allocation lands
        self.telemetry.sparsity.note_pool_bytes(tree_bytes(self.cache))

    def _get_chunk_spls(self, cq: Optional[int], cf: Optional[int],
                        ckv: Optional[int] = None, horizon: bool = False):
        """Jitted SPLS chunk step for one capacity-bucket triple (dense
        compute uses the single ``(None, None, None)`` entry); ``horizon``
        adds the liveness-mask + decode-anchor operands of the
        horizon-finalized vote."""
        key = (cq, cf, ckv, horizon)
        fn = self._chunk_spls_jits.get(key)
        if fn is None:
            cfg, cb = self.cfg, self._compute
            need = self._vote_need
            if horizon:
                fn = jax.jit(
                    lambda p, c, pc, pp, tb, start, toks, valid, k, lv, lk:
                    paged_prefill_chunk_spls(cfg, p, c, pc, pp, tb, start,
                                             toks, valid, k, q_capacity=cq,
                                             ffn_capacity=cf,
                                             kv_capacity=ckv,
                                             compute_backend=cb,
                                             live=lv, last_keep=lk,
                                             kv_vote_need=need),
                    donate_argnums=(1, 2, 3))
            else:
                fn = jax.jit(
                    lambda p, c, pc, pp, tb, start, toks, valid, k:
                    paged_prefill_chunk_spls(cfg, p, c, pc, pp, tb, start,
                                             toks, valid, k, q_capacity=cq,
                                             ffn_capacity=cf,
                                             compute_backend=cb),
                    donate_argnums=(1, 2, 3))
            self._chunk_spls_jits[key] = fn
        return fn

    # ------------------------------------------------------------------
    @property
    def stats(self) -> dict:
        """Back-compat dict view over the typed instruments: the
        scheduler counters (live ``CounterDictView``), pool gauges, and
        capacity-controller snapshots, assembled fresh per read."""
        out = {**self.sched.stats,
               "pages_in_use": self.pool.pages_in_use,
               "peak_pages": self.pool.peak_in_use,
               "free_pages": self.pool.free_pages,
               "guard_trips": self.pool.guard_trips,
               "compute_backend": self._compute,
               "flops_saved_pct": self.sched.flops_saved_pct()}
        if self._cap_q is not None:
            out["capacity_q"] = self._cap_q.snapshot()
            out["capacity_ffn"] = self._cap_f.snapshot()
        if self._cap_kv is not None:
            out["capacity_kv"] = self._cap_kv.snapshot()
        return out

    def submit(self, req: Request) -> None:
        lp = int(req.prompt.shape[0])
        if lp > self.scfg.max_len:
            raise ValueError(f"request {req.rid}: prompt {lp} exceeds "
                             f"max_len {self.scfg.max_len}")
        self.sched.submit(req, [int(t) for t in np.asarray(req.prompt)],
                          req.max_new_tokens)
        # recorded only once the scheduler accepted it (a rejected
        # request would leave an unclosed lifecycle span)
        self.telemetry.request_submitted(req.rid, lp)

    # ------------------------------------------------------------------
    def _dest_slots(self, st: SeqState, n: int) -> np.ndarray:
        """(n,) flat page-slot destinations for logical slots [0, n)."""
        pages = np.asarray(st.pages, np.int64)
        sl = np.arange(n)
        return pages[sl // self.page_size] * self.page_size \
            + sl % self.page_size

    def _table_row(self, st: SeqState) -> np.ndarray:
        row = np.full((self.pages_per_seq,), NULL_PAGE, np.int32)
        row[:len(st.pages)] = st.pages
        return row

    def _full_prefill(self, st: SeqState) -> None:
        tel = self.telemetry
        tel.span_begin("full_prefill", rid=st.req.rid,
                       args={"prompt_len": st.prompt_len})
        toks = jnp.asarray(st.tokens, jnp.int32)[None, :]
        logits, dense_cache = self._prefill(self.params, toks)
        if self._prune:
            keep = keep_from_votes(self._votes(self.params, toks[0]),
                                   self.cfg.n_heads,
                                   self.scfg.spls_prune_vote)
        else:
            keep = np.ones((st.prompt_len,), bool)
        keep_idx = np.nonzero(keep)[0]
        n_kept = len(keep_idx)
        if not self.sched.grow_to(st, n_kept):
            # st itself was preempted (span unwound by the preempt/abort
            # telemetry); prefill recomputes later
            return
        dest = self._dest_slots(st, n_kept)
        self.cache, self.pos_pages = scatter_prefill(
            self.cache, self.pos_pages, dense_cache,
            jnp.asarray(keep_idx, jnp.int32), jnp.asarray(dest, jnp.int32))
        st.kv_len = n_kept
        st.cur_pos = st.prompt_len
        st.prefilled = st.prompt_len
        # whole-prompt prefill runs dense/simulation compute (packed
        # capacities apply on the chunked path); charged dense == executed
        self.sched.note_flops(chunk_flops(self.cfg, st.prompt_len,
                                          st.prompt_len))
        if self._prune:
            self.sched.note_prune(st.prompt_len, n_kept)
            tel.sparsity.note_prune(st.prompt_len, n_kept)
        tel.span_end("full_prefill", rid=st.req.rid,
                     args={"kept": n_kept})
        self._emit_first(st, logits[0, -1])

    def _chunk_prefill(self, st: SeqState) -> None:
        tel = self.telemetry
        cs = self.sched.cfg.prefill_chunk
        start = st.prefilled                 # == st.kv_len (columns stay
        #                          dense until the end-of-prefill compaction)
        valid = min(cs, st.prompt_len - start)
        if not self.sched.grow_to(st, start + valid):
            return   # preempted/aborted; telemetry unwound the track
        tel.span_begin("prefill_chunk", rid=st.req.rid,
                       args={"start": start, "valid": valid})
        chunk = np.zeros((cs,), np.int32)
        chunk[:valid] = st.tokens[start:start + valid]
        if self.cfg.spls.enabled:
            from repro.core.planner import horizon_update_live
            from repro.core.topk import topk_count
            if self.pred_cache is None:
                self.pred_cache = init_pred_cache(self.cfg, self._n_pages,
                                                  self.page_size)
                tel.sparsity.note_pool_bytes(tree_bytes(self.cache),
                                             tree_bytes(self.pred_cache))
            k = topk_count(st.prompt_len, self.cfg.spls.k_ratio)
            packed = self._cap_q is not None
            cq = self._cap_q.capacity() if packed else None
            cf = (self._cap_f.capacity()
                  if packed and self.cfg.spls.ffn_sparsity else None)
            ckv = (self._cap_kv.capacity()
                   if self._cap_kv is not None else None)
            horizon = self._horizon
            S = self.pages_per_seq * self.page_size
            last_keep = st.prompt_len - 1
            args = [self.params, self.cache, self.pred_cache,
                    self.pos_pages, jnp.asarray(self._table_row(st)),
                    jnp.asarray(start, jnp.int32),
                    jnp.asarray(chunk)[None, :],
                    jnp.asarray(valid, jnp.int32), jnp.asarray(k, jnp.int32)]
            if horizon is not None:
                if st.live is None:
                    st.live = np.ones((S,), bool)
                args += [jnp.asarray(st.live),
                         jnp.asarray(last_keep, jnp.int32)]
            (logits, self.cache, self.pred_cache, self.pos_pages,
             kv_any, counts) = self._get_chunk_spls(
                cq, cf, ckv, horizon is not None)(*args)
            if self._prune:
                # cross-chunk vote accumulator: a head's "some row kept
                # this column" bit only ever turns on, so OR is exact
                votes = np.asarray(kv_any).reshape(self.cfg.n_heads, -1)
                st.head_votes = (votes if st.head_votes is None
                                 else st.head_votes | votes)
            if horizon is not None:
                # finalize columns whose probation expired below the
                # cross-head vote threshold (and mirror the device's
                # kv_capacity pack decision for this chunk's own columns
                # -- core.planner owns both)
                st.live = horizon_update_live(
                    st.live, st.head_votes.sum(axis=0), start=start,
                    valid=valid, chunk=cs, horizon=horizon,
                    last_keep=last_keep, vote_need=self._vote_need,
                    kv_capacity=ckv, metrics=tel.metrics)
            if packed:
                # the host readback of the critical counts syncs on the
                # chunk step; only the packed path pays it (dense compute
                # discards the counts and stays fully async)
                n_q, n_f, n_kv = (int(v)
                                  for v in np.asarray(counts).max(axis=0))
                self._cap_q.observe(n_q)
                if n_q > cq:
                    self._cap_q.note_overflow()
                tel.sparsity.note_capacity("q", cq, n_q, n_q > cq)
                if self.cfg.spls.ffn_sparsity:
                    self._cap_f.observe(n_f)
                    if n_f > cf:
                        self._cap_f.note_overflow()
                    tel.sparsity.note_capacity("ffn", cf, n_f, n_f > cf)
                if ckv is not None:
                    self._cap_kv.observe(n_kv)
                    if n_kv > ckv:
                        self._cap_kv.note_overflow()
                    tel.sparsity.note_capacity("kv", ckv, n_kv, n_kv > ckv)
            self.sched.note_flops(chunk_flops(
                self.cfg, cs, start + valid, q_rows=cq, ffn_rows=cf,
                kv_rows=ckv))
        else:
            logits, self.cache, self.pos_pages = self._chunk(
                self.params, self.cache, self.pos_pages,
                jnp.asarray(self._table_row(st)),
                jnp.asarray(start, jnp.int32), jnp.asarray(chunk)[None, :],
                jnp.asarray(valid, jnp.int32))
            self.sched.note_flops(chunk_flops(self.cfg, cs, start + valid))
        st.prefilled += valid
        st.kv_len += valid
        st.cur_pos += valid
        self.sched.stats["prefill_chunks"] += 1
        tel.span_end("prefill_chunk", rid=st.req.rid)
        if st.phase == "decode":
            if self._prune and self.cfg.spls.enabled:
                self._finish_chunk_prune(st)
            self._emit_first(st, logits[0, 0])

    def _finish_chunk_prune(self, st: SeqState) -> None:
        """The page-prune vote is final once every prompt row has voted
        (votes are monotone in rows, so pruning any earlier would diverge
        from the full-prefill decision): threshold the accumulated head
        votes, compact kept columns -- in original order, the same layout
        ``scatter_prefill`` produces -- into the front of the sequence's
        own pages, and free the tail."""
        tel = self.telemetry
        tel.span_begin("prune_compact", rid=st.req.rid)
        Lp = st.prompt_len
        S = self.pages_per_seq * self.page_size
        tel.sparsity.note_votes(st.head_votes[:, :Lp])
        votes = st.head_votes.sum(axis=0).astype(np.int32)
        keep = keep_from_votes(votes[:Lp], self.cfg.n_heads,
                               self.scfg.spls_prune_vote)
        if st.live is not None:
            # horizon-finalized columns are gone even if they gathered
            # votes later could not reach them; and a voted own-column the
            # kv_capacity pack dropped was never materialized -- the final
            # keep set must honor both (the decode anchor stays live)
            keep &= st.live[:Lp]
        n_kept = int(keep.sum())
        keep_slots = np.zeros((S,), bool)
        keep_slots[:Lp] = keep
        self.cache, self.pos_pages = self._compact(
            self.cache, self.pos_pages, jnp.asarray(self._table_row(st)),
            jnp.asarray(keep_slots))
        needed = self.pool.pages_for(n_kept)
        if needed < len(st.pages):
            self.pool.free(st.pages[needed:])
            st.pages = st.pages[:needed]
        st.kv_len = n_kept
        st.head_votes = None
        self.sched.note_prune(Lp, n_kept)
        tel.sparsity.note_prune(Lp, n_kept)
        tel.span_end("prune_compact", rid=st.req.rid,
                     args={"kept": n_kept, "prompt_len": Lp})

    def _emit_first(self, st: SeqState, logits_row: jax.Array) -> None:
        tok = int(self._pick(logits_row))
        st.req.output.append(tok)
        st.budget -= 1
        self.telemetry.first_token(st.req.rid)

    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One engine iteration; returns number of slots decoded."""
        self.sched.admit()

        for st in self.sched.plan_prefills():
            if self.sched.slots[st.slot] is not st:
                continue  # preempted by an earlier prefill this tick
            if self.sched.use_chunks(st.prompt_len):
                self._chunk_prefill(st)
            else:
                self._full_prefill(st)
        self._retire_finished()  # prefill-emitted token may hit eos/budget

        # grow pages for every decode-ready row (may preempt the youngest)
        for st in list(self.sched.decode_ready()):
            if self.sched.slots[st.slot] is not st or st.budget <= 0:
                continue
            self.sched.grow_to(st, st.kv_len + 1)
        active = [st for st in self.sched.decode_ready() if st.budget > 0
                  and len(st.pages) * self.page_size > st.kv_len]

        n_decoded = 0
        if active:
            self.telemetry.span_begin("decode_tick",
                                      args={"n_active": len(active)})
            n_slots = self.scfg.n_slots
            tables = np.full((n_slots, self.pages_per_seq), NULL_PAGE,
                             np.int32)
            kv_len = np.zeros((n_slots,), np.int32)
            cur_pos = np.zeros((n_slots,), np.int32)
            tokens = np.zeros((n_slots, 1), np.int32)
            for st in active:
                tables[st.slot] = self._table_row(st)
                kv_len[st.slot] = st.kv_len
                cur_pos[st.slot] = st.cur_pos
                tokens[st.slot, 0] = st.req.output[-1]
            logits, self.cache, self.pos_pages = self._decode(
                self.params, self.cache, self.pos_pages,
                jnp.asarray(tables), jnp.asarray(kv_len),
                jnp.asarray(cur_pos), jnp.asarray(tokens))
            nxt = self._pick(logits[:, 0])
            for st in active:
                st.req.output.append(int(nxt[st.slot]))
                st.kv_len += 1
                st.cur_pos += 1
                st.budget -= 1
            n_decoded = len(active)
            self.telemetry.span_end("decode_tick")
            self.telemetry.tokens_decoded([st.req.rid for st in active])

        self._retire_finished()
        # sample after retirement so a drained pool reads 0 in the gauge
        self.telemetry.sparsity.observe_pool(self.pool)
        return n_decoded

    def _retire_finished(self) -> None:
        # requests the scheduler aborted (optimistic admission that never
        # fit; see Scheduler.grow_to) retire with whatever they generated
        for req in self.sched.aborted:
            req.done = True
            self._retired.append(req)
            self.telemetry.request_aborted(req.rid)
        self.sched.aborted.clear()
        for st in list(self.sched.active()):
            req = st.req
            hit_eos = req.eos_id is not None and req.eos_id in req.output
            if (st.phase == "decode"
                    and (st.budget <= 0 or hit_eos
                         or st.cur_pos >= self.scfg.max_len - 1)):
                req.done = True
                self.sched.retire(st)
                self._retired.append(req)
                self.telemetry.request_retired(req.rid)

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        """Tick until everything drains; returns the requests retired
        during this call, in retirement order."""
        start = len(self._retired)
        for _ in range(max_ticks):
            self.tick()
            if self.sched.idle():
                break
        return self._retired[start:]
