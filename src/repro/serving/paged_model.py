"""Model execution against the block-pool paged KV cache.

Mirrors the dense serving path in :mod:`repro.models.model` (scan over
periods, one lowered period body) but threads :class:`PagedKVCache` pages,
a shared block table, and original-position ids instead of a dense
``(B, KV, max_len, Dh)`` slab:

* :func:`paged_decode_step` -- one batched decode tick.  Each layer writes
  the new token's K/V into the slot the block table names (inactive rows
  write to the null page) and attends through the paged decode backends
  (``xla_paged_decode`` / ``pallas_paged_decode``).
* :func:`paged_prefill_chunk` -- chunked prefill: one prompt chunk (padded
  to a static chunk size) is projected at its original positions, written
  into freshly allocated slots, and attends over *all* slots written so far
  -- cross-chunk causal attention, which is what lets the scheduler
  interleave long prefills with decode ticks.
* :func:`scatter_prefill` -- full-prefill ingestion: takes the dense cache
  :func:`repro.models.model.prefill` produced, gathers the kept columns
  (SPLS page pruning), and scatters them into pages.

All functions are functional: caches/pos_pages go in, updated ones come
out; the engine owns jit boundaries and the host-side pool bookkeeping.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import resolve_backend, get_backend
from repro.models.attention import output_proj, project_kv, project_qkv
from repro.models.common import dtype_of, rms_norm, softcap as _softcap
from repro.models.model import embed_inputs, head_logits
from repro.models.moe import ffn_forward

from .pager import POS_SENTINEL, PagedKVCache

__all__ = ["paged_decode_step", "paged_prefill_chunk",
           "paged_prefill_chunk_spls", "scatter_prefill", "compact_slots"]


def _cast_params(pparams, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, pparams)


def _write_token(kc: PagedKVCache, k_new: jax.Array, v_new: jax.Array,
                 flat: jax.Array) -> PagedKVCache:
    """Scatter one token's K/V (B, KV, 1, Dh) into flat page slots (B,)."""
    KV, N, ps, Dh = kc.k_pages.shape
    kf = kc.k_pages.reshape(KV, N * ps, Dh)
    vf = kc.v_pages.reshape(KV, N * ps, Dh)
    kf = kf.at[:, flat].set(jnp.moveaxis(k_new[:, :, 0], 0, 1))
    vf = vf.at[:, flat].set(jnp.moveaxis(v_new[:, :, 0], 0, 1))
    return PagedKVCache(kf.reshape(KV, N, ps, Dh), vf.reshape(KV, N, ps, Dh))


def _decode_flat_slots(tables: jax.Array, kv_len: jax.Array,
                       page_size: int) -> jax.Array:
    """(B,) flat page-slot index for each row's next write (slot kv_len).
    Inactive rows (all-null tables, kv_len 0) resolve to the null page."""
    page = jnp.take_along_axis(tables, (kv_len // page_size)[:, None],
                               axis=1)[:, 0]
    return page * page_size + kv_len % page_size


def _chunk_slots(table: jax.Array, pos_pages: jax.Array, start: jax.Array,
                 valid: jax.Array, CS: int):
    """Chunk destination slots + pos_pages update, shared by both chunked
    prefill paths (slot == original position during prefill).

    Padded rows (idx >= valid) all scatter to null-page slot 0 and write
    POS_SENTINEL -- not their would-be position -- so the null page stays
    inert: a real id there could pass a ``pos - id < window`` test on a
    row that reads the null page through an unallocated table entry.
    Returns ``(sl (CS,) slot ids, flat (CS,) scatter targets,
    new_pos_pages)``.
    """
    N, ps = pos_pages.shape
    idx = jnp.arange(CS, dtype=jnp.int32)
    sl = start + idx
    page = table[sl // ps]
    flat = jnp.where(idx < valid, page * ps + sl % ps, 0)
    pos_pages = pos_pages.reshape(N * ps).at[flat].set(
        jnp.where(idx < valid, sl, POS_SENTINEL)).reshape(N, ps)
    return sl, flat, pos_pages


def _write_chunk_kv(kc: PagedKVCache, k_new: jax.Array, v_new: jax.Array,
                    flat: jax.Array) -> PagedKVCache:
    """Scatter a chunk's K/V rows (1, KV, CS, Dh) into flat page slots."""
    KV, N, ps, Dh = kc.k_pages.shape
    kf = kc.k_pages.reshape(KV, N * ps, Dh).at[:, flat].set(k_new[0])
    vf = kc.v_pages.reshape(KV, N * ps, Dh).at[:, flat].set(v_new[0])
    return PagedKVCache(kf.reshape(KV, N, ps, Dh), vf.reshape(KV, N, ps, Dh))


def _residual_ffn(cfg: ArchConfig, blk, bp, x: jax.Array, h: jax.Array,
                  ffn_leader: jax.Array = None, ffn_comp=None,
                  compute_backend: str = "dense") -> jax.Array:
    """Attention residual + optional post-norms + FFN residual, shared by
    the decode and chunked-prefill scan bodies.  ``ffn_leader`` (local row
    ids) enables simulation-mode sparse FFN: similar tokens copy their MFI
    leader's output.  ``ffn_comp`` (a :class:`~repro.core.sparse_exec.Compaction`)
    switches to *packed* sparse FFN through the compute-backend registry:
    only critical rows are computed, leaders broadcast to followers."""
    if cfg.use_post_norm:
        h = rms_norm(h, bp["post_ln1"], cfg.norm_eps)
    x = x + h
    if blk.has_ffn:
        xn2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
        if ffn_comp is not None and not blk.use_moe:
            from repro.sparse_compute import packed_mlp
            h2 = packed_mlp(cfg, bp["ffn"], xn2, ffn_comp, compute_backend)
        else:
            h2 = ffn_forward(cfg, blk.use_moe, bp["ffn"], xn2)
            if ffn_leader is not None:
                h2 = jnp.take_along_axis(h2, ffn_leader[..., None], axis=-2)
        if cfg.use_post_norm:
            h2 = rms_norm(h2, bp["post_ln2"], cfg.norm_eps)
        x = x + h2
    return x


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def paged_decode_step(cfg: ArchConfig, params, cache, pos_pages: jax.Array,
                      tables: jax.Array, kv_len: jax.Array,
                      cur_pos: jax.Array, tokens: jax.Array,
                      backend: Optional[str] = None):
    """One batched decode tick over the paged cache.

    tokens: (B, 1) int32; tables: (B, P); kv_len: (B,) written slots;
    cur_pos: (B,) original position of this token.  Every layer writes the
    token's K/V at slot ``kv_len`` (whose page the engine has already
    ensured) and attends over ``kv_len + 1`` slots.  Returns
    ``(logits (B, 1, V), new_cache, new_pos_pages)``.
    """
    ps = pos_pages.shape[1]
    N = pos_pages.shape[0]
    flat = _decode_flat_slots(tables, kv_len, ps)
    pos_pages = pos_pages.reshape(N * ps).at[flat].set(cur_pos) \
        .reshape(N, ps)
    n_valid = kv_len + 1
    name = resolve_backend(backend or cfg.attn_backend, cfg, L=N * ps,
                           decode=True, paged=True)
    fn = get_backend(name)
    dtype = dtype_of(cfg.compute_dtype)
    x = embed_inputs(cfg, params, tokens)

    def scan_body(x, inp):
        pparams, pcache = inp
        pparams = _cast_params(pparams, dtype)
        new_caches = []
        for blk, bp, kc in zip(cfg.period, pparams, pcache):
            xn = rms_norm(x, bp["ln1"], cfg.norm_eps)
            q, k_new, v_new = project_qkv(cfg, bp["attn"], xn,
                                          cur_pos[:, None], "structured")
            kc = _write_token(kc, k_new, v_new, flat)
            o = fn(cfg, q[:, :, :, 0], kc.k_pages, kc.v_pages,
                   pos_pages=pos_pages, tables=tables, kv_len=n_valid,
                   pos=cur_pos, window=blk.window)
            h = output_proj(cfg, bp["attn"], o[:, :, :, None], "structured")
            x = _residual_ffn(cfg, blk, bp, x, h)
            new_caches.append(kc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(scan_body, x, (params["periods"], cache))
    return head_logits(cfg, params, x), new_cache, pos_pages


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

def paged_prefill_chunk(cfg: ArchConfig, params, cache,
                        pos_pages: jax.Array, table: jax.Array,
                        start: jax.Array, tokens: jax.Array,
                        valid: jax.Array):
    """Process one prompt chunk for a single sequence (B = 1).

    tokens: (1, CS) chunk padded to the static chunk size; start: ()
    written slots so far (== original position base: the chunked path never
    prunes, so slot index == position); valid: () real tokens in this
    chunk; table: (P,) the sequence's block table (pages for
    ``start + valid`` slots already allocated).  Chunk queries attend over
    every slot written so far *plus* this chunk (cross-chunk causal
    attention by original position ids).  Returns
    ``(logits (1, 1, V) for the chunk's last valid position, new_cache,
    new_pos_pages)``; only the final chunk's logits are meaningful (they
    seed the first decoded token) -- the LM head is not run for the other
    ``CS - 1`` rows.
    """
    assert cfg.causal, "chunked prefill needs causal attention"
    _, CS = tokens.shape
    N, ps = pos_pages.shape
    S = table.shape[0] * ps
    dtype = dtype_of(cfg.compute_dtype)

    sl, flat, pos_pages = _chunk_slots(table, pos_pages, start, valid, CS)
    positions = sl[None, :]                            # original ids
    n_valid = start + valid
    pg = pos_pages[table].reshape(S)                   # slot -> original id
    slot_idx = jnp.arange(S)

    x = embed_inputs(cfg, params, tokens)

    def attend(blk, q, kc):
        KV = kc.k_pages.shape[0]
        kg = kc.k_pages[:, table][None].reshape(1, KV, S, -1)
        vg = kc.v_pages[:, table][None].reshape(1, KV, S, -1)
        Dh = q.shape[-1]
        s = jnp.einsum("bkgqd,bkld->bkgql", q, kg) * (Dh ** -0.5)
        s = _softcap(s, cfg.attn_softcap)
        m = slot_idx[None, :] < n_valid
        m = m & (pg[None, :] <= positions[0][:, None])
        if blk.window is not None:
            m = m & (positions[0][:, None] - pg[None, :] < blk.window)
        s = jnp.where(m[None, None, None], s, jnp.asarray(-1e30, s.dtype))
        a = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
        return jnp.einsum("bkgql,bkld->bkgqd", a, vg)

    def scan_body(x, inp):
        pparams, pcache = inp
        pparams = _cast_params(pparams, dtype)
        new_caches = []
        for blk, bp, kc in zip(cfg.period, pparams, pcache):
            xn = rms_norm(x, bp["ln1"], cfg.norm_eps)
            q, k_new, v_new = project_qkv(cfg, bp["attn"], xn, positions,
                                          "structured")
            kc = _write_chunk_kv(kc, k_new, v_new, flat)
            o = attend(blk, q, kc)
            h = output_proj(cfg, bp["attn"], o, "structured")
            x = _residual_ffn(cfg, blk, bp, x, h)
            new_caches.append(kc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(scan_body, x, (params["periods"], cache))
    x_last = jax.lax.dynamic_slice_in_dim(x, valid - 1, 1, axis=1)
    return head_logits(cfg, params, x_last), new_cache, pos_pages


# ---------------------------------------------------------------------------
# SPLS chunked prefill (the paper's progressive generation scheme, Sec. IV-C)
# ---------------------------------------------------------------------------

def paged_prefill_chunk_spls(cfg: ArchConfig, params, cache, pred_cache,
                             pos_pages: jax.Array, table: jax.Array,
                             start: jax.Array, tokens: jax.Array,
                             valid: jax.Array, topk_k: jax.Array,
                             q_capacity: Optional[int] = None,
                             ffn_capacity: Optional[int] = None,
                             kv_capacity: Optional[int] = None,
                             compute_backend: str = "dense",
                             live: Optional[jax.Array] = None,
                             last_keep: Optional[jax.Array] = None,
                             kv_vote_need: int = 1):
    """One SPLS prompt chunk for a single sequence (B = 1).

    The streaming driver of the unified planner
    (:class:`repro.core.planner.PlanContext`): every layer (1) extends its
    paged *predictor* cache with the chunk's predicted K heads as int8
    codes + per-token scale (``PlanContext.encode_pred_qk``; dequantized
    on read, bit-for-bit), (2) emits a plan block for the chunk's rows
    against every column seen so far (``PlanContext.plan_block``:
    bisection top-k with a *traced* ``topk_k``, so one jit covers every
    prompt length; O(chunk * S) memory, never a full PAM), and (3)
    executes the chunk rows in simulation-mode SPLS over all written KV
    slots.  The math is row-for-row identical to the progressive
    full-prefill path (``prefill(..., plan_mode="progressive")``), which
    is what makes chunked and whole-prompt serving agree bit-for-bit.

    Chunks must be window-aligned (``start`` and the chunk size multiples
    of ``cfg.spls.window``) so similarity windows coincide with the
    unchunked pipeline's.

    **End-to-end sparse compute** (``compute_backend`` ``"packed_xla"`` /
    ``"packed_pallas"``, static capacities ``q_capacity`` /
    ``ffn_capacity``): the Q projection and attention run only on the
    *cross-head union* of critical rows packed to ``q_capacity`` (leaders
    broadcast to their followers through the compaction's read slots), and
    the FFN runs only on FFN-critical rows packed to ``ffn_capacity``.
    At full capacities the packed path is bit-for-bit the dense
    (``"dense"``) path; below them, overflow rows fall back to their
    window leader (:func:`repro.core.sparse_exec.compact_rows`).

    **Horizon-finalized column votes** (``live`` / ``kv_capacity`` /
    ``last_keep``; see :mod:`repro.core.planner`): ``live`` (S,) marks
    columns the engine's finite ``vote_horizon`` already finalized as
    pruned -- they are denied attention (masked out of every layer's
    score block), while the prediction/vote pipeline itself stays
    horizon-independent so the vote trajectory matches the
    end-of-prefill path's (the monotonicity the tests pin).  With ``kv_capacity`` set (the
    ``vote_horizon == 1`` mode), layer 0's plan block additionally
    decides which of the chunk's *own* columns won the cross-head
    keep vote (``kv_vote_need`` agreeing heads -- the engine passes
    ``ceil(spls_prune_vote * H)``, the same bar the end-of-prefill vote
    applies) **before** formal K/V generation; only those (packed to
    ``kv_capacity``, plus the forced ``last_keep`` anchor) are projected
    and written -- the K/V-projection share of the paper's end-to-end
    sparsity.  All layers share layer 0's decision (a page slot is shared
    by every layer, exactly like the end-of-prefill prune vote).  With
    ``live=None`` and ``kv_capacity=None`` the path is bit-for-bit
    today's end-of-prefill vote: every column materializes until the vote
    finalizes with the last chunk, after which the engine runs
    :func:`compact_slots`.

    Returns ``(logits (1, 1, V), new_cache, new_pred_cache, new_pos_pages,
    kv_any, crit_counts)`` with ``kv_any (1, KV, G, S)`` layer 0's per-head
    column-keep contribution for the engine's vote accumulator and
    ``crit_counts (n_periods, 3)`` the per-period max of (union-critical
    rows, FFN-critical rows, vote-surviving own columns) -- the capacity
    controllers' observations.
    """
    assert cfg.causal, "chunked prefill needs causal attention"
    from repro.core.planner import (PlanContext, own_column_keep,
                                    pack_within_capacity)
    from repro.core.sparse_exec import (_masked_softmax, compact_rows,
                                        gather_rows, pack_by_mask)
    from repro.sparse_compute import is_packed, packed_project_q

    from .pager import PredKCache

    _, CS = tokens.shape
    if CS % cfg.spls.window:
        raise ValueError(
            f"prefill_chunk ({CS}) must be a multiple of the SPLS "
            f"similarity window ({cfg.spls.window}): chunk boundaries must "
            f"align with similarity windows for chunked prefill to "
            f"reproduce the full-prefill plan (set "
            f"ServeConfig.auto_align_chunk=True to round up automatically)")
    packed = is_packed(compute_backend)
    if kv_capacity is not None:
        assert packed, "kv_capacity rides on a packed compute backend"
        assert live is not None and last_keep is not None, \
            "kv_capacity needs the liveness mask and the decode anchor"
    Cq = min(q_capacity or CS, CS)
    Cf = min(ffn_capacity or CS, CS)
    Ckv = min(kv_capacity, CS) if kv_capacity is not None else None
    N, ps = pos_pages.shape
    S = table.shape[0] * ps
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    scfg = cfg.spls
    dtype = dtype_of(cfg.compute_dtype)
    ctx = PlanContext.for_config(cfg, mode="structured")

    sl, flat, pos_pages = _chunk_slots(table, pos_pages, start, valid, CS)
    positions = sl[None, :]
    n_valid = start + valid
    slot_idx = jnp.arange(S)

    x = embed_inputs(cfg, params, tokens)

    def scan_body(carry, inp):
        if Ckv is not None:
            x, kv_written_c, live_all_c, n_kv_c = carry
        else:
            x = carry
            kv_written_c = live_all_c = n_kv_c = None
        pparams, pcache, ppred, p_idx = inp
        pparams = _cast_params(pparams, dtype)
        new_caches, new_preds = [], []
        kv_any0 = None
        counts = jnp.zeros((3,), jnp.int32)
        ridx = jnp.arange(CS, dtype=jnp.int32)
        for bi, (blk, bp, kc, pk) in enumerate(
                zip(cfg.period, pparams, pcache, ppred)):
            xn = rms_norm(x, bp["ln1"], cfg.norm_eps)
            # -- prediction: extend the predictor code pages, emit the
            # plan block (all plan math lives in core.planner)
            qh, k_codes, k_scale = ctx.encode_pred_qk(bp["attn"], xn)
            codes_pg = pk.codes.reshape(KV, N * ps, Dh).at[:, flat] \
                .set(k_codes).reshape(KV, N, ps, Dh)
            scale_pg = pk.scale.reshape(N * ps).at[flat].set(k_scale) \
                .reshape(N, ps)
            pk = PredKCache(codes=codes_pg, scale=scale_pg)
            kh_all = ctx.decode_pred_k(codes_pg[:, table].reshape(KV, S, Dh),
                                       scale_pg[table].reshape(S),
                                       dtype=dtype)[None]
            # the prediction/vote pipeline is deliberately horizon-
            # independent (no col_live): finalized columns are denied
            # materialization and attention below, but still occupy their
            # top-k candidacy -- this keeps the vote trajectory identical
            # to the end-of-prefill path's, which is what makes the kept
            # set monotone in the horizon
            pb = ctx.plan_block(qh, kh_all, k=topk_k, row0=start,
                                n_valid_rows=valid, n_cols=n_valid)
            if kv_any0 is None:
                kv_any0 = pb.kv_any
            lead_local = pb.q_leader - start
            # capacity-controller observations: union of per-head critical
            # rows (the Q pack) and valid FFN-critical rows (padded rows
            # report FFN-critical but never count)
            crit_any = jnp.any(pb.q_critical, axis=(1, 2))     # (1, CS)
            n_ffn = (pb.ffn_critical[0] & (ridx < valid)).sum()
            if Ckv is not None and bi == 0:
                # layer 0 decides which of this chunk's own columns get a
                # K/V projection at all (vote_horizon == 1: the chunk's
                # own plan votes are final); later layers and periods
                # reuse the carried decision -- lax.cond runs the
                # decision exactly once per chunk
                def _decide(_):
                    ok = own_column_keep(
                        pb.kv_any, start=start, chunk=CS, valid=valid,
                        last_keep=last_keep, vote_need=kv_vote_need)
                    anchor = start + ridx == last_keep
                    w = pack_within_capacity(ok, Ckv, anchor=anchor)
                    live_new = jax.lax.dynamic_update_slice(
                        jnp.pad(live, (0, CS)), w, (start,))[:S]
                    return w, live_new, ok.sum().astype(jnp.int32)

                kv_written_c, live_all_c, n_kv_c = jax.lax.cond(
                    p_idx == 0, _decide,
                    lambda _: (kv_written_c, live_all_c, n_kv_c), None)
            if Ckv is not None:
                counts = jnp.maximum(counts, jnp.stack(
                    [crit_any.sum(), n_ffn, n_kv_c]).astype(jnp.int32))
            else:
                counts = jnp.maximum(counts, jnp.stack(
                    [crit_any.sum(), n_ffn,
                     jnp.zeros((), jnp.int32)]).astype(jnp.int32))
            # -- formal K/V at original positions.  Dense for every chunk
            # row by default (columns must materialize until the prune
            # vote finalizes); under vote_horizon == 1 the project_kv
            # seam runs packed over only the vote-surviving columns.
            if packed:
                if Ckv is not None:
                    # pack order over the anchor-reserved written set: at
                    # most Ckv True rows, so every written column lands
                    # in the perm (filler slots scatter to the null page)
                    kv_perm, _ = pack_by_mask(kv_written_c, Ckv)
                    k_new, v_new = project_kv(
                        cfg, bp["attn"], xn, positions, "structured",
                        perm=kv_perm, compute_backend=compute_backend)
                    flat_kv = jnp.where(jnp.take(kv_written_c, kv_perm),
                                        jnp.take(flat, kv_perm), 0)
                    kc = _write_chunk_kv(kc, k_new, v_new, flat_kv)
                else:
                    k_new, v_new = project_kv(cfg, bp["attn"], xn,
                                              positions, "structured")
                    kc = _write_chunk_kv(kc, k_new, v_new, flat)
            else:
                q, k_new, v_new = project_qkv(cfg, bp["attn"], xn,
                                              positions, "structured")
                kc = _write_chunk_kv(kc, k_new, v_new, flat)
            kg = kc.k_pages[:, table][None].reshape(1, KV, S, Dh)
            vg = kc.v_pages[:, table][None].reshape(1, KV, S, Dh)
            mask = pb.mask
            if blk.window is not None:
                mask = mask & (positions[0][:, None] - slot_idx[None, :]
                               < blk.window)
            if Ckv is not None:
                # columns finalized dead (earlier chunks) or dropped by
                # the kv pack (this chunk's own) were never projected /
                # are pruned: deny them to every layer's attention
                mask = mask & live_all_c
            elif live is not None:
                # finite horizon without K/V packing: earlier-finalized
                # columns are pruned; this chunk's own columns always
                # materialize
                mask = mask & live
            # row selection: the two modes differ only in *which* q/mask
            # rows the shared score/softmax/AV block sees.
            if packed:
                # packed SPLS attention: compute only the union rows'
                # scores (every head's leaders are in the union), then
                # every row reads its leader's packed slot.  Bit-for-bit
                # the simulation-mode path at Cq == CS; overflow rows
                # fall back to their window leader.
                qcomp = compact_rows(crit_any, Cq, leader=lead_local,
                                     window=scfg.window)
                q_sel = packed_project_q(cfg, bp["attn"], xn, sl,
                                         qcomp.perm[0], compute_backend)
                perm_idx = qcomp.perm[:, None, None, :, None]
                mask_sel = jnp.take_along_axis(mask, perm_idx, axis=-2)
            else:
                # simulation-mode SPLS attention over all written slots:
                # similar rows use their leader's Q row and mask row
                # (leaders are window-local, hence chunk-local)
                q_sel = gather_rows(q, lead_local)
                mask_sel = jnp.take_along_axis(mask, lead_local[..., None],
                                               axis=-2)
            s = jnp.einsum("bkgqd,bkld->bkgql", q_sel, kg) * (Dh ** -0.5)
            if cfg.attn_softcap is not None:
                s = jnp.tanh(s / cfg.attn_softcap) * cfg.attn_softcap
            a = _masked_softmax(s, mask_sel)
            o = jnp.einsum("bkgql,bkld->bkgqd", a, vg)
            if packed:
                o = jnp.take_along_axis(o, qcomp.src_slot[..., None],
                                        axis=-2)
            h = output_proj(cfg, bp["attn"], o, "structured")
            ffn_comp = None
            if packed and scfg.ffn_sparsity and not blk.use_moe:
                ffn_comp = compact_rows(pb.ffn_critical, Cf,
                                        leader=pb.ffn_leader - start,
                                        window=scfg.window)
            x = _residual_ffn(cfg, blk, bp, x, h,
                              ffn_leader=(pb.ffn_leader - start
                                          if scfg.ffn_sparsity else None),
                              ffn_comp=ffn_comp,
                              compute_backend=compute_backend)
            new_caches.append(kc)
            new_preds.append(pk)
        carry_out = ((x, kv_written_c, live_all_c, n_kv_c)
                     if Ckv is not None else x)
        return carry_out, (tuple(new_caches), tuple(new_preds), kv_any0,
                           counts)

    if Ckv is not None:
        carry0 = (x, jnp.zeros((CS,), bool), live, jnp.zeros((), jnp.int32))
    else:
        carry0 = x
    carry, (new_cache, new_pred, kv_any, counts) = jax.lax.scan(
        scan_body, carry0,
        (params["periods"], cache, pred_cache,
         jnp.arange(cfg.n_periods, dtype=jnp.int32)))
    x = carry[0] if Ckv is not None else carry
    x_last = jax.lax.dynamic_slice_in_dim(x, valid - 1, 1, axis=1)
    return (head_logits(cfg, params, x_last), new_cache, new_pred,
            pos_pages, jax.tree.map(lambda a: a[0], kv_any), counts)


def compact_slots(cache, pos_pages: jax.Array, table: jax.Array,
                  keep: jax.Array) -> Tuple[tuple, jax.Array]:
    """End-of-prefill SPLS compaction, in place within a sequence's pages.

    keep: (S,) bool over the sequence's logical slots (slot == original
    position during prefill; slots past the prompt are False).  Kept
    slots move -- in original order, matching :func:`scatter_prefill`'s
    compacted layout exactly -- to the first ``n_kept`` slots of the
    sequence's *own* pages; the freed tail is sentinel-filled so window
    masks never admit a stale id.  No transient page allocation: the
    engine frees the pages past ``ceil(n_kept / ps)`` afterwards.
    """
    N, ps = pos_pages.shape
    S = table.shape[0] * ps
    sl = jnp.arange(S)
    flat = table[sl // ps] * ps + sl % ps
    perm = jnp.argsort(~keep, stable=True)
    n_kept = keep.sum()
    src = flat[perm]
    pos_flat = pos_pages.reshape(N * ps)
    # unallocated table tails alias null-page slots: every such collision
    # writes POS_SENTINEL (j >= n_kept), so the scatter stays deterministic
    vals = jnp.where(sl < n_kept, pos_flat[src], POS_SENTINEL)
    pos_pages = pos_flat.at[flat].set(vals).reshape(N, ps)

    new_blocks = []
    for pc in cache:
        nP, KV, N_, ps_, Dh = pc.k_pages.shape
        kf = pc.k_pages.reshape(nP, KV, N_ * ps_, Dh)
        vf = pc.v_pages.reshape(nP, KV, N_ * ps_, Dh)
        kf = kf.at[:, :, flat].set(kf[:, :, src])
        vf = vf.at[:, :, flat].set(vf[:, :, src])
        new_blocks.append(PagedKVCache(kf.reshape(nP, KV, N_, ps_, Dh),
                                       vf.reshape(nP, KV, N_, ps_, Dh)))
    return tuple(new_blocks), pos_pages


# ---------------------------------------------------------------------------
# full-prefill ingestion (with SPLS page pruning)
# ---------------------------------------------------------------------------

def scatter_prefill(cache, pos_pages: jax.Array, dense_cache,
                    keep_idx: jax.Array, flat: jax.Array
                    ) -> Tuple[tuple, jax.Array]:
    """Move a full prefill's kept KV columns into pages.

    dense_cache: the per-layer dense cache from
    :func:`repro.models.model.prefill` on a batch of one (arrays
    ``(n_periods, 1, KV, S, Dh)`` per period block); keep_idx: (n_kept,)
    original positions that survive SPLS pruning (all positions when
    pruning is off); flat: (n_kept,) destination flat page slots.  The
    kept columns land compacted; ``pos_pages`` records their original ids.
    """
    N, ps = pos_pages.shape
    pos_pages = pos_pages.reshape(N * ps).at[flat] \
        .set(keep_idx.astype(jnp.int32)).reshape(N, ps)

    new_blocks = []
    for pc, dc in zip(cache, dense_cache):
        nP, KV, N_, ps_, Dh = pc.k_pages.shape
        rows_k = dc.k[:, 0][:, :, keep_idx]            # (nP, KV, n_kept, Dh)
        rows_v = dc.v[:, 0][:, :, keep_idx]
        kf = pc.k_pages.reshape(nP, KV, N_ * ps_, Dh).at[:, :, flat] \
            .set(rows_k).reshape(nP, KV, N_, ps_, Dh)
        vf = pc.v_pages.reshape(nP, KV, N_ * ps_, Dh).at[:, :, flat] \
            .set(rows_v).reshape(nP, KV, N_, ps_, Dh)
        new_blocks.append(PagedKVCache(kf, vf))
    return tuple(new_blocks), pos_pages
