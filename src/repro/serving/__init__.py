"""Paged SPLS-aware serving subsystem.

Block-pool KV cache (:mod:`pager`), paged model execution
(:mod:`paged_model`), continuous-batching scheduler with chunked prefill
and preemption (:mod:`scheduler`), and the engines (:mod:`engine`).
See README.md in this directory for the page lifecycle and the SPLS
page-pruning semantics.
"""

from .pager import (NULL_PAGE, POS_SENTINEL, PagedKVCache, PagePool,
                    init_paged_cache, init_pos_pages, spls_token_keep)
from .paged_model import (paged_decode_step, paged_prefill_chunk,
                          scatter_prefill)
from .scheduler import Scheduler, SchedulerConfig, SeqState
from .engine import PagedServingEngine, Request, ServeConfig, ServingEngine

__all__ = [
    "NULL_PAGE", "POS_SENTINEL", "PagedKVCache", "PagePool",
    "init_paged_cache", "init_pos_pages", "spls_token_keep",
    "paged_decode_step", "paged_prefill_chunk", "scatter_prefill",
    "Scheduler", "SchedulerConfig", "SeqState",
    "PagedServingEngine", "Request", "ServeConfig", "ServingEngine",
]
