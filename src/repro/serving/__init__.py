"""Paged SPLS-aware serving subsystem.

Block-pool KV cache (:mod:`pager`), paged model execution
(:mod:`paged_model`), continuous-batching scheduler with chunked prefill
and preemption (:mod:`scheduler`), and the engines (:mod:`engine`).
See README.md in this directory for the page lifecycle and the SPLS
page-pruning semantics.
"""

from .pager import (NULL_PAGE, POS_SENTINEL, PagedKVCache, PagePool,
                    PredKCache, init_paged_cache, init_pos_pages,
                    init_pred_cache, spls_token_keep, spls_token_votes)
from .paged_model import (compact_slots, paged_decode_step,
                          paged_prefill_chunk, paged_prefill_chunk_spls,
                          scatter_prefill)
from .scheduler import Scheduler, SchedulerConfig, SeqState
from .engine import PagedServingEngine, Request, ServeConfig, ServingEngine

__all__ = [
    "NULL_PAGE", "POS_SENTINEL", "PagedKVCache", "PagePool", "PredKCache",
    "init_paged_cache", "init_pos_pages", "init_pred_cache",
    "spls_token_keep", "spls_token_votes",
    "compact_slots", "paged_decode_step", "paged_prefill_chunk",
    "paged_prefill_chunk_spls", "scatter_prefill",
    "Scheduler", "SchedulerConfig", "SeqState",
    "PagedServingEngine", "Request", "ServeConfig", "ServingEngine",
]
