"""Block-pool paged KV cache: pages, free list, and SPLS page pruning.

The pool owns ``n_pages`` fixed-size pages per layer, shared by every
sequence in the engine.  A sequence's KV lives in the pages its block table
names; pages are allocated on demand (one page covers ``page_size`` token
slots across *all* KV heads of every layer) and returned to the free list
when the request retires or is preempted.

Page 0 is the reserved **null page**: it fills unallocated block-table
entries and absorbs writes from inactive batch rows.  Reads of it are
always masked (slot >= kv_len), so its contents never matter.

SPLS page pruning (the serving-side realization of the paper's zero-column
detection): at prefill time, prompt positions whose K/V columns the
:class:`~repro.core.spls.SparsityPlan` marks dead receive **no slot at
all** -- the kept columns are compacted into pages and each slot remembers
its *original* position id (``pos_pages``), which is what keeps RoPE,
causality, and sliding windows exact after compaction.  A pruned request
therefore occupies ``ceil(kept / page_size)`` pages instead of
``ceil(prompt / page_size)``: the paper's inter-row sparsity becomes
measurable pool headroom and admission capacity (cf. SpAtten's cascade
token pruning).
"""

from __future__ import annotations

import math
from collections import deque
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["NULL_PAGE", "POS_SENTINEL", "PagedKVCache", "PredKCache",
           "PagePool", "init_paged_cache", "init_pos_pages",
           "init_pred_cache", "keep_from_votes", "spls_token_keep",
           "spls_token_votes"]

NULL_PAGE = 0
# pos_pages filler for never-written slots.  Correctness never rests on it:
# unwritten/stale slots are excluded by the `slot < kv_len` mask (and by
# `id <= position` in the chunked-prefill path).  The sentinel only keeps
# such slots inert in position arithmetic -- a window test `pos - id <
# window` on a sentinel is far *below* the window, i.e. it would pass, so
# the kv_len mask must always stay ANDed in.
POS_SENTINEL = 1 << 30


class PagedKVCache(NamedTuple):
    """One attention layer's page pool (leading ``n_periods`` axis when part
    of the stacked model cache): k/v_pages ``(..., KV, n_pages, ps, Dh)``."""

    k_pages: jax.Array
    v_pages: jax.Array


class PredKCache(NamedTuple):
    """One period block's paged SPLS predictor cache, stored as **int8
    HLog codes + per-token scale** instead of dequantized values.

    codes: ``(n_periods, KV, n_pages, ps, Dh)`` int8 symmetric
    quantization codes of the predicted K heads; scale:
    ``(n_periods, n_pages, ps)`` float32 per-token quantization scale
    (per-token scales are what make the streaming predictor reproducible,
    so one scalar per slot covers all ``KV * Dh`` code elements).  The
    planner dequantizes on read
    (:meth:`repro.core.planner.PlanContext.decode_pred_k`) bit-for-bit to
    the value the old float cache stored -- the log-domain projection is
    deterministic on integer codes -- at 1 byte/element + 4 bytes/slot
    instead of 4 bytes/element (float32 compute dtype: ~-75% pred-cache
    pool bytes; bf16: ~-50%).
    """

    codes: jax.Array
    scale: jax.Array


class PagePool:
    """Free-list allocator over the shared page pool (host-side).

    Page ids are plain ints; the engine owns the device arrays.  Allocation
    is all-or-nothing so a request can never deadlock holding half of what
    it needs.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("pool needs >= 2 pages (page 0 is the null page)")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: deque = deque(range(1, n_pages))
        self._allocated: set = set()
        self.peak_in_use = 0
        # double-free / foreign-free guard trips (the raise below): a
        # plain counter so telemetry can surface trips even when the
        # caller swallows the exception
        self.guard_trips = 0

    # ------------------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable pages (the null page is never handed out)."""
        return self.n_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.capacity - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.page_size) if n_tokens > 0 else 0

    # ------------------------------------------------------------------
    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` pages from the free list, or None if short."""
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        self._allocated.update(pages)
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return pages

    def free(self, pages: List[int]) -> None:
        """Return pages to the free list.

        Raises on a double-free or a foreign/null page: a page id freed
        twice would sit on the free list twice, get handed to *two*
        sequences, and silently cross-contaminate their KV -- the classic
        allocator bug, caught here instead of as corrupted generations.
        """
        for p in pages:
            if p not in self._allocated:
                self.guard_trips += 1
                raise ValueError(
                    f"page {p} is not currently allocated "
                    f"({'null page' if p == NULL_PAGE else 'double-free or foreign page'}); "
                    f"refusing to free it twice -- two sequences would "
                    f"share one page")
            self._allocated.discard(p)
            self._free.append(p)


# ---------------------------------------------------------------------------
# device-side storage
# ---------------------------------------------------------------------------

def init_paged_cache(cfg, n_pages: int, page_size: int):
    """Stacked-over-periods paged cache pytree, mirroring
    :func:`repro.models.model.init_cache` but with pages instead of a dense
    ``(B, KV, max_len, Dh)`` slab: one :class:`PagedKVCache` per period
    block with arrays ``(n_periods, KV, n_pages, ps, Dh)``.

    The paged engine is attention-only (asserted by the engine); there is no
    paged analogue of the Mamba state because SSM state is O(1) per slot.
    """
    from repro.models.common import dtype_of

    dtype = dtype_of(cfg.compute_dtype)
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim

    def one_block(blk):
        assert blk.mixer == "attn", "paged cache covers attention blocks only"
        # distinct buffers (not one aliased zeros array): the engine donates
        # the cache to its jits, and XLA rejects donating a buffer twice
        shape = (cfg.n_periods, KV, n_pages, page_size, Dh)
        return PagedKVCache(k_pages=jnp.zeros(shape, dtype),
                            v_pages=jnp.zeros(shape, dtype))

    return tuple(one_block(blk) for blk in cfg.period)


def init_pos_pages(n_pages: int, page_size: int) -> jax.Array:
    """(n_pages, ps) int32 original-position ids, sentinel-filled.  Shared by
    every layer: all layers write the same token at the same slot."""
    return jnp.full((n_pages, page_size), POS_SENTINEL, jnp.int32)


def init_pred_cache(cfg, n_pages: int, page_size: int):
    """Paged SPLS predictor cache: per attention block, the predicted K
    heads of every written slot as int8 codes + per-token scale
    (:class:`PredKCache`), page-parallel with the KV pool (same block
    table, same flat slots).

    This is what makes chunked prefill's per-chunk plan construction
    O(chunk * L): each chunk's plan block scores the chunk's predicted Q
    rows against *every previously seen column's* predicted K without
    recomputing earlier chunks.  Only allocated when SPLS is enabled; the
    int8 code layout cuts the extra pool bytes to ~a quarter of the KV
    dtype's (one code byte per element plus one float32 scale per slot).
    """
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.spls.quant_bits > 8:
        raise ValueError(
            f"int8 predictor-cache codes require spls.quant_bits <= 8, "
            f"got {cfg.spls.quant_bits}")

    def one_block(blk):
        assert blk.mixer == "attn", "paged cache covers attention blocks only"
        return PredKCache(
            codes=jnp.zeros((cfg.n_periods, KV, n_pages, page_size, Dh),
                            jnp.int8),
            scale=jnp.zeros((cfg.n_periods, n_pages, page_size),
                            jnp.float32))

    return tuple(one_block(blk) for blk in cfg.period)


# ---------------------------------------------------------------------------
# SPLS page pruning policy
# ---------------------------------------------------------------------------

def spls_token_votes(cfg, params, prompt: jax.Array) -> jax.Array:
    """(Lp,) int32 head votes for keeping each prompt KV column.

    Runs the paper's SPLS prediction (HLog PAM -> bisection top-k ->
    zero-column detection) on the layer-0 normalized input and counts how
    many of the H = KV*G heads retain each column.  Routed through the
    unified planner's progressive driver
    (:meth:`repro.core.planner.PlanContext.iter_blocks` over
    window-aligned row blocks, per-token quantization): peak memory is
    O(row_block * Lp) -- the dense O(Lp^2) plan is never materialized --
    and the votes are bit-identical to what the streaming chunked-prefill
    predictor accumulates chunk by chunk, for any chunking.  Pure and
    jit-safe; the engine jits it once per prompt shape.
    """
    from repro.core.planner import progressive_plan_blocks, votes_from_kv_any
    from repro.models.common import dtype_of, rms_norm

    dtype = dtype_of(cfg.compute_dtype)
    blk0 = jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, jax.tree.map(lambda a: a[0], params["periods"][0]))
    x = params["embed"][prompt[None, :]].astype(dtype)
    if cfg.scale_embedding:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    xn = rms_norm(x, blk0["ln1"], cfg.norm_eps)

    kv_any = None
    for blk in progressive_plan_blocks(cfg, blk0, xn, votes_only=True):
        kv_any = blk if kv_any is None else (kv_any | blk)
    return votes_from_kv_any(kv_any)


def keep_from_votes(votes: np.ndarray, n_heads: int,
                    vote: float) -> np.ndarray:
    """Threshold head votes into a keep mask; the final token is always
    kept (it anchors the decode continuation)."""
    need = max(1, math.ceil(vote * n_heads))
    keep = np.asarray(votes) >= need
    keep = np.array(keep)
    keep[-1] = True
    return keep


def spls_token_keep(cfg, params, prompt: jax.Array,
                    vote: float = 0.5) -> np.ndarray:
    """(Lp,) bool keep mask for prompt KV columns, from the layer-0 plan.

    A token keeps its page slot iff at least ``ceil(vote * H)`` of the
    H = KV*G heads retain its column (``vote=0`` degenerates to the
    any-head union, ``vote=1`` demands unanimity) -- the MFI idea of
    cross-head agreement applied to serving memory, since a page slot is
    shared by every head and, SpAtten-style, by every layer.  All-True
    when SPLS is disabled.
    """
    Lp = int(prompt.shape[0])
    if not cfg.spls.enabled:
        return np.ones((Lp,), bool)
    votes = spls_token_votes(cfg, params, prompt)
    return keep_from_votes(np.asarray(votes), cfg.n_heads, vote)
