"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Spins up a continuous-batching engine on a smoke-scale model and drives a
synthetic request stream through it (batched prefill+decode on CPU).
``--paged`` selects the block-pool paged engine (chunked prefill,
admission keyed on free pages, SPLS page pruning); the default is the
dense fixed-slot engine.  Paged serving requires attention-only periods
(SSM state is O(1) per slot and is not paged).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--spls", action="store_true")
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--page-size", type=int, default=8)
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config
    from repro.models import init_params
    from repro.serving import (PagedServingEngine, Request, ServeConfig,
                               ServingEngine)

    cfg = get_config(args.arch).smoke()
    cfg = dataclasses.replace(cfg, remat=False)
    if args.spls and cfg.has_attn:
        from repro.core.spls import SPLSConfig
        cfg = dataclasses.replace(cfg, spls=SPLSConfig(
            enabled=True, k_ratio=0.25, s_threshold=0.6, f_threshold=2,
            window=4, causal=cfg.causal))
    if cfg.input_mode != "tokens":
        print(f"{cfg.name}: embeddings-input arch; engine demo uses tokens "
              "-- skipping")
        return 0
    if args.paged and cfg.has_mamba:
        print(f"{cfg.name}: hybrid/SSM arch; paged engine is attention-only "
              "-- skipping")
        return 0

    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(n_slots=args.slots,
                       max_len=args.prompt_len + args.max_new + 8,
                       page_size=args.page_size)
    eng = (PagedServingEngine if args.paged else ServingEngine)(
        cfg, params, scfg)
    reqs = []
    for i in range(args.requests):
        prompt = jax.random.randint(jax.random.PRNGKey(i),
                                    (args.prompt_len,), 0, cfg.vocab_size)
        r = Request(rid=i, prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(r)
        eng.submit(r)
    done = eng.run_until_drained(max_ticks=1000)
    out = {"requests": len(reqs), "retired": len(done),
           "all_done": all(r.done for r in reqs),
           "outputs": {r.rid: r.output[:8] for r in reqs[:4]}}
    if args.paged:
        out["pool"] = {k: eng.stats[k] for k in
                       ("peak_pages", "preemptions", "prefill_chunks")}
    print(json.dumps(out, indent=1))
    return 0 if out["all_done"] else 1


if __name__ == "__main__":
    sys.exit(main())
