import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Drive the full dry-run sweep: every (arch x shape) cell on the single-pod
mesh (roofline baselines) and the multi-pod mesh (the pod-axis proof).

Each cell runs in a fresh subprocess (jax caches device state and compiled
programs; isolation also makes one cell's failure non-fatal) and results
append to a JSON-lines file, so the sweep is resumable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun_all \
      [--out results/dryrun.jsonl] [--multi-pod] [--only arch:shape ...]
"""

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path


def _done_keys(path: Path):
    done = set()
    if path.exists():
        for line in path.read_text().splitlines():
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"], r.get("spls", False)))
            except Exception:
                pass
    return done


def run_one(arch: str, shape: str, multi_pod: bool, spls: bool,
            timeout: int = 3600):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape]
    if multi_pod:
        cmd.append("--multi-pod")
    if spls:
        cmd.append("--spls")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
        if proc.returncode == 0:
            return json.loads(proc.stdout)
        return {"arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16", "spls": spls,
                "error": proc.stderr[-2000:], "wall_s": time.time() - t0}
    except subprocess.TimeoutExpired:
        return {"arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16", "spls": spls,
                "error": f"timeout {timeout}s", "wall_s": time.time() - t0}


def main(argv=None):
    from repro.configs.registry import all_cells

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--meshes", default="16x16,2x16x16")
    ap.add_argument("--only", nargs="*", default=None,
                    help="arch:shape filters")
    ap.add_argument("--spls", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args(argv)

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    done = _done_keys(out)

    cells = list(all_cells(include_skipped=True))
    if args.only:
        want = {tuple(x.split(":")) for x in args.only}
        cells = [c for c in cells if c in want]

    meshes = args.meshes.split(",")
    total = len(cells) * len(meshes)
    i = 0
    for mesh in meshes:
        multi = mesh == "2x16x16"
        for arch, shape in cells:
            i += 1
            key = (arch, shape, mesh, args.spls)
            if key in done:
                continue
            print(f"[{i}/{total}] {arch} x {shape} on {mesh}"
                  f"{' +spls' if args.spls else ''} ...", flush=True)
            res = run_one(arch, shape, multi, args.spls, args.timeout)
            with out.open("a") as f:
                f.write(json.dumps(res, default=str) + "\n")
            status = ("SKIP" if res.get("skipped")
                      else "ERR" if "error" in res else
                      f"ok compile={res.get('compile_s')}s "
                      f"dom={res.get('roofline', {}).get('dominant')}")
            print(f"    -> {status}", flush=True)
    print("sweep complete:", out)


if __name__ == "__main__":
    main()
