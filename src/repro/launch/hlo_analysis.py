"""Roofline-term extraction from compiled (post-SPMD, post-fusion) HLO text.

Why parse text at all?  ``compiled.cost_analysis()`` on the CPU backend
counts every ``while`` body exactly once -- but layer stacks are scanned, so
a 126-layer model would be undercounted 126x.  And collective bytes are not
reported at all.  We therefore walk the computation call graph ourselves:

  * ``while`` trip counts come from ``backend_config known_trip_count``
    (fallback: the compare constant in the condition computation);
  * FLOPs: 2 * prod(out_shape) * prod(lhs_contracting_dims) per ``dot``
    (matmuls dominate transformer FLOPs; elementwise ops are not counted --
    the compute roofline term is an MXU term);
  * HBM traffic: operand + result bytes of every materializing instruction
    (fusion boundaries in the optimized HLO are exactly the points where
    buffers hit memory);
  * collective bytes per op type (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), result-shape sized.

All numbers are PER DEVICE: the HLO is the per-device SPMD program.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

__all__ = ["parse_hlo_stats", "parse_hlo_collectives", "collective_bytes"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_SKIP_OPS = {"bitcast", "tuple", "get-tuple-element", "parameter",
             "constant", "after-all", "partition-id", "replica-id",
             "opt-barrier", "iota"}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
# name = <shape (possibly a tuple with layouts)> <op>(%operand...
# The operand lookahead admits tuple-shaped operands "((s32[], ...)" too --
# jit'd while loops carry their carry as one tuple operand.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((?=%|\)|\(|s32|f32|bf16|pred|u32)")
_SHAPE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_WHILE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIPS = re.compile(r'known_trip_count.{0,8}?"n"\s*:\s*"?(\d+)')
_CONST = re.compile(r"%?([\w\.\-]+)\s*=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")
_COMPARE = re.compile(r"compare\(([^)]*)\)")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_HDR_PARAM = re.compile(r"([\w\.\-]+):\s*((?:" + "|".join(_DTYPE_BYTES)
                        + r")\[[0-9,]*\]|\([^)]*\))")
_DOT_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(text: str) -> Tuple[str, List[int]]:
    m = _SHAPE.search(text)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


class _HLO:
    def __init__(self, hlo: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry = None
        self.shapes: Dict[str, str] = {}   # instr name -> shape text
        cur = None
        for line in hlo.splitlines():
            if line[:1] not in (" ", "\t", ""):
                m = _COMP_HDR.match(line)
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                    for pname, pshape in _HDR_PARAM.findall(line):
                        self.shapes[pname] = pshape
                    continue
            s = line.strip()
            if cur is not None and s and s != "}":
                self.comps[cur].append(s)
                mi = _INSTR.match(line)
                if mi:
                    self.shapes[mi.group(1)] = mi.group(2)

    def trip_count(self, while_line: str, cond: str) -> int:
        m = _TRIPS.search(while_line)
        if m:
            return int(m.group(1))
        consts = {}
        for ln in self.comps.get(cond, []):
            for name, val in _CONST.findall(ln):
                consts[name] = int(val)
        for ln in self.comps.get(cond, []):
            mc = _COMPARE.search(ln)
            if mc:
                for name, val in consts.items():
                    if name in mc.group(1):
                        return val
        return max(consts.values()) if consts else 1


def parse_hlo_stats(hlo: str) -> Dict[str, float]:
    """Trip-corrected per-device {dot_flops, traffic_bytes, coll:*, total}."""
    H = _HLO(hlo)
    memo: Dict[str, Dict[str, float]] = {}

    def analyze(name: str, stack: tuple) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        out: Dict[str, float] = defaultdict(float)
        for ln in H.comps.get(name, []):
            mi = _INSTR.match(ln)
            if not mi:
                continue
            iname, rshape, op = mi.groups()
            if op == "while":
                mw = _WHILE.search(ln)
                if mw and mw.group(2) not in stack:
                    trips = H.trip_count(ln, mw.group(1))
                    inner = analyze(mw.group(2), stack + (name,))
                    for k, v in inner.items():
                        out[k] += v * trips
                continue
            if op in ("call", "conditional", "async-start"):
                for callee in re.findall(
                        r"(?:to_apply|called_computations=\{)%?([\w\.\-]+)",
                        ln):
                    if callee in H.comps and callee not in stack:
                        inner = analyze(callee, stack + (name,))
                        for k, v in inner.items():
                            out[k] += v
                continue
            if op in _SKIP_OPS:
                continue

            result_bytes = _shape_bytes(rshape)
            # operand bytes: arguments inside the op's parens
            paren = ln[ln.index(op + "(") + len(op) + 1:]
            depth, args = 1, ""
            for ch in paren:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                args += ch
            operand_bytes = 0
            for oname in _OPERANDS.findall(args):
                operand_bytes += _shape_bytes(H.shapes.get(oname, ""))

            base_op = op.replace("-start", "").replace("-done", "")
            if base_op in _COLL_OPS:
                if op.endswith("-done"):
                    continue
                out[f"coll:{base_op}"] += result_bytes
                out["traffic_bytes"] += result_bytes + operand_bytes
                continue

            out["traffic_bytes"] += result_bytes + operand_bytes
            if op == "dot":
                _, odims = _first_shape_dims(rshape)
                oelems = 1
                for d in odims:
                    oelems *= d
                lhs = _OPERANDS.findall(args)
                cd = _DOT_CDIMS.search(ln)
                k = 1
                if lhs and cd is not None:
                    _, ldims = _first_shape_dims(H.shapes.get(lhs[0], ""))
                    if cd.group(1):
                        for idx in cd.group(1).split(","):
                            i = int(idx)
                            if i < len(ldims):
                                k *= ldims[i]
                out["dot_flops"] += 2.0 * oelems * k
        memo[name] = dict(out)
        return memo[name]

    totals = analyze(H.entry, ()) if H.entry else {}
    result = {"dot_flops": totals.get("dot_flops", 0.0),
              "traffic_bytes": totals.get("traffic_bytes", 0.0)}
    coll_total = 0.0
    for k, v in totals.items():
        if k.startswith("coll:"):
            result[k] = v
            coll_total += v
    result["collective_bytes"] = coll_total
    return result


def parse_hlo_collectives(hlo: str) -> Dict[str, int]:
    """Back-compat wrapper: per-op-type collective bytes + total."""
    stats = parse_hlo_stats(hlo)
    out = {k[5:]: int(v) for k, v in stats.items() if k.startswith("coll:")}
    out["total"] = int(stats.get("collective_bytes", 0))
    return out


def collective_bytes(compiled) -> Dict[str, int]:
    return parse_hlo_collectives(compiled.as_text())
