"""Abstract input specs (ShapeDtypeStruct stand-ins) for every
(architecture x input-shape x step-kind) cell -- no device allocation.

``train_*`` cells lower ``train_step``; ``prefill_*`` cells lower the
prefill step (where the SPLS technique runs); ``decode_*`` / ``long_*``
cells lower ``serve_step`` -- one new token against a KV cache of seq_len,
per the assignment.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCfg
from repro.models import init_cache
from repro.models.common import dtype_of
from repro.models.model import abstract_params
from repro.sharding.rules import (batch_sharding, cache_sharding,
                                  param_sharding)

__all__ = ["input_specs", "abstract_params_sharded", "abstract_cache_sharded"]


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def abstract_params_sharded(cfg: ArchConfig, mesh: Mesh):
    ab = abstract_params(cfg)
    shd = param_sharding(cfg, mesh, ab)
    return jax.tree.map(lambda a, s: _sds(a.shape, a.dtype, s), ab, shd), shd


def abstract_cache_sharded(cfg: ArchConfig, mesh: Mesh, batch: int,
                           max_len: int):
    ab = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    shd = cache_sharding(cfg, mesh, ab, batch, max_len)
    return jax.tree.map(lambda a, s: _sds(a.shape, a.dtype, s), ab, shd), shd


def input_specs(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh
                ) -> Dict[str, Any]:
    """Abstract step inputs for one cell.

    Returns a dict with key "kind" plus the abstract arguments:
      train:   params, batch {inputs, labels}
      prefill: params, inputs
      decode:  params, cache, tokens, pos
    """
    B, L = shape.global_batch, shape.seq_len
    bsh = batch_sharding(mesh, B)
    cdt = dtype_of(cfg.compute_dtype)
    params, pshard = abstract_params_sharded(cfg, mesh)

    if shape.kind == "train":
        if cfg.input_mode == "tokens":
            inputs = _sds((B, L), jnp.int32, bsh)
        else:
            inputs = _sds((B, L, cfg.d_model), cdt, bsh)
        batch = {"inputs": inputs, "labels": _sds((B, L), jnp.int32, bsh)}
        return {"kind": "train", "params": params, "param_sharding": pshard,
                "batch": batch}

    if shape.kind == "prefill":
        if cfg.input_mode == "tokens":
            inputs = _sds((B, L), jnp.int32, bsh)
        else:
            inputs = _sds((B, L, cfg.d_model), cdt, bsh)
        return {"kind": "prefill", "params": params,
                "param_sharding": pshard, "inputs": inputs}

    # decode: one new token, cache holds seq_len positions
    cache, cshard = abstract_cache_sharded(cfg, mesh, B, L)
    if cfg.input_mode == "tokens":
        tokens = _sds((B, 1), jnp.int32, bsh)
    else:
        tokens = _sds((B, 1, cfg.d_model), cdt, bsh)
    pos = _sds((B,), jnp.int32, bsh)
    return {"kind": "decode", "params": params, "param_sharding": pshard,
            "cache": cache, "cache_sharding": cshard, "tokens": tokens,
            "pos": pos}
