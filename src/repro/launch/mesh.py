"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state -- the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds the mesh.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # AxisType landed in jax 0.5; the pinned 0.4.x has no explicit axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None

__all__ = ["make_production_mesh", "make_cpu_mesh", "mesh_axis_sizes"]


def _make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh with explicit Auto axis types where supported."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: (pod,) data, model.  ``pod`` is an outer data-parallel axis whose
    collectives cross the inter-pod interconnect (DCI); ``data`` is in-pod
    DP; ``model`` is tensor parallelism over the fastest ICI dimension.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_cpu_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over however many (host) devices exist -- tests."""
    return _make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
