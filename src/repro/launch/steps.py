"""Step functions: training (with gradient accumulation) and serving.

Builders return plain functions of abstract-shardable arguments; callers
jit them inside an ``axis_rules`` context so the model's logical sharding
constraints bind to the active mesh.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import decode_step, loss_fn, prefill
from repro.optim import AdamWConfig, adamw_update
from repro.sharding.logical import constrain

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step",
           "make_loss_grad"]


def _with_backend(cfg: ArchConfig, attn_backend: Optional[str]) -> ArchConfig:
    """Pin an attention backend for this step (None keeps cfg's choice)."""
    if attn_backend is None or attn_backend == cfg.attn_backend:
        return cfg
    return dataclasses.replace(cfg, attn_backend=attn_backend)


def make_loss_grad(cfg: ArchConfig, n_micro: int = 1) -> Callable:
    """(params, batch) -> (grads, metrics), with microbatch accumulation.

    The global batch is reshaped to (n_micro, B/n_micro, ...) and scanned;
    gradients are averaged across microbatches.  Activation live range is
    one microbatch, which is what lets the 405B train_4k cell fit HBM.
    """

    def loss_for(params, batch):
        return loss_fn(cfg, params, batch)

    def loss_grad(params, batch):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)
            return grads, metrics

        B = batch["inputs"].shape[0]
        assert B % n_micro == 0, (B, n_micro)
        micro = jax.tree.map(
            lambda x: x.reshape(n_micro, B // n_micro, *x.shape[1:]), batch)
        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, _), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_micro, acc, grads)
            return (acc, loss_acc + loss / n_micro), None

        (grads, loss), _ = jax.lax.scan(body, (zero_g, jnp.zeros(())), micro)
        return grads, {"loss": loss}

    return loss_grad


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    schedule: Callable, n_micro: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_grad = make_loss_grad(cfg, n_micro)

    def train_step(params, opt_state, batch):
        grads, metrics = loss_grad(params, batch)
        lr = schedule(opt_state.count)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params, lr)
        metrics = {**metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig,
                      attn_backend: Optional[str] = None) -> Callable:
    """(params, inputs) -> (logits, cache).  SPLS runs here when enabled.

    ``attn_backend`` pins an attention backend for the whole prefill
    (e.g. ``"pallas_flash"`` on TPU); default defers to ``cfg``/auto.
    """
    cfg = _with_backend(cfg, attn_backend)

    def prefill_step(params, inputs):
        return prefill(cfg, params, inputs)

    return prefill_step


def make_serve_step(cfg: ArchConfig,
                    attn_backend: Optional[str] = None) -> Callable:
    """(params, cache, tokens, pos) -> (logits, new_cache).

    ``attn_backend`` pins the decode backend (e.g. ``"pallas_flash_decode"``).
    """
    cfg = _with_backend(cfg, attn_backend)

    def serve_step(params, cache, tokens, pos):
        return decode_step(cfg, params, cache, tokens, pos)

    return serve_step
