"""Launchers: production mesh, dry-run, training and serving entry points.

NOTE: do not import ``dryrun`` from here -- it sets XLA_FLAGS at import time
and must only run as __main__ in a fresh process.
"""

from .mesh import make_cpu_mesh, make_production_mesh, mesh_axis_sizes
from .steps import (make_loss_grad, make_prefill_step, make_serve_step,
                    make_train_step)
