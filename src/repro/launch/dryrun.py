import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory / FLOPs / collective-traffic analysis.

The two lines above MUST run before any jax import (jax locks the device
count on first init); they give this process 512 placeholder CPU devices so
``jax.make_mesh`` can build the 16x16 single-pod and 2x16x16 multi-pod
meshes.  Nothing is allocated: inputs are ShapeDtypeStructs and the step is
only lowered and compiled.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
      --shape train_4k [--multi-pod] [--spls] [--out results.json]
"""

import argparse
import json
import sys
import time

import jax

from repro.configs.registry import get_config, get_shape
from repro.launch.hlo_analysis import parse_hlo_stats
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.launch.specs import input_specs
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)
from repro.optim import AdamWConfig, adamw_init
from repro.optim.schedules import warmup_cosine
from repro.sharding.logical import axis_rules
from repro.sharding.rules import activation_rules, opt_state_sharding

# TPU v5e hardware constants for the roofline terms
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             spls: bool = False, n_micro: int = None,
             donate: bool = True) -> dict:
    import dataclasses

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape_name not in cfg.supported_shapes:
        return {"arch": arch, "shape": shape_name,
                "mesh": f"{'2x' if multi_pod else ''}16x16", "spls": spls,
                "skipped": True,
                "reason": "unsupported shape (see DESIGN.md)"}
    if spls and cfg.has_attn:
        from repro.core.spls import SPLSConfig
        cfg = dataclasses.replace(cfg, spls=SPLSConfig(
            enabled=True, k_ratio=0.12, s_threshold=0.6, f_threshold=6,
            window=8, causal=cfg.causal,
            q_capacity_ratio=0.5, kv_capacity_ratio=0.75))

    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = mesh_axis_sizes(mesh)
    n_chips = 1
    for v in sizes.values():
        n_chips *= v

    specs = input_specs(cfg, shape, mesh)
    t0 = time.time()
    with axis_rules(activation_rules(mesh), mesh):
        if specs["kind"] == "train":
            mb = n_micro or (cfg.microbatch or {}).get(shape_name, 1)
            data_par = n_chips // sizes.get("model", 1)
            per_shard = max(shape.global_batch // data_par, 1)
            n_acc = max(per_shard // mb, 1)
            step = make_train_step(
                cfg, AdamWConfig(moment_dtype=None),
                warmup_cosine(3e-4, 100, 10000), n_micro=n_acc)
            opt_abs = jax.eval_shape(
                lambda p: adamw_init(AdamWConfig(), p), specs["params"])
            oshard = opt_state_sharding(specs["param_sharding"], opt_abs)
            opt_abs = jax.tree.map(
                lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                                  sharding=s),
                opt_abs, oshard)
            fn = jax.jit(step, donate_argnums=(0, 1) if donate else ())
            lowered = fn.lower(specs["params"], opt_abs, specs["batch"])
        elif specs["kind"] == "prefill":
            step = make_prefill_step(cfg)
            lowered = jax.jit(step).lower(specs["params"], specs["inputs"])
        else:
            step = make_serve_step(cfg)
            fn = jax.jit(step, donate_argnums=(1,) if donate else ())
            lowered = fn.lower(specs["params"], specs["cache"],
                               specs["tokens"], specs["pos"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    stats = parse_hlo_stats(compiled.as_text())

    # All parsed numbers are PER DEVICE (the HLO is the SPMD program), with
    # while-loop trip counts applied -- XLA's own cost_analysis() counts
    # scanned layer bodies once, so we parse the HLO ourselves (see
    # hlo_analysis.py) and keep the raw numbers for reference.
    flops_dev = stats["dot_flops"]
    bytes_dev = stats["traffic_bytes"]
    coll_dev = stats["collective_bytes"]

    model_flops = _model_flops(cfg, shape)
    result = {
        "arch": arch, "shape": shape_name, "kind": specs["kind"],
        "mesh": f"{'2x' if multi_pod else ''}16x16", "chips": n_chips,
        "spls": spls, "skipped": False,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes_per_device": getattr(mem, "alias_size_in_bytes", None),
        },
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collective_breakdown": {k[5:]: v for k, v in stats.items()
                                 if k.startswith("coll:")},
        "xla_cost_analysis_raw": {"flops": float(cost.get("flops", 0.0)),
                                  "bytes": float(cost.get("bytes accessed", 0.0))},
        "model_flops_total": model_flops,
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_dev / ICI_BW,
        },
    }
    terms = result["roofline"]
    dom = max(terms, key=terms.get)
    result["roofline"]["dominant"] = dom
    total_hlo_flops = flops_dev * n_chips
    result["model_flops_ratio"] = (model_flops / total_hlo_flops
                                   if total_hlo_flops else None)
    return result


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D for MoE; decode: D=B tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--spls", action="store_true",
                    help="enable the paper's SPLS sparsity in the step")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    res = run_cell(args.arch, args.shape, args.multi_pod, args.spls,
                   args.n_micro)
    js = json.dumps(res, indent=2, default=str)
    print(js)
    if args.out:
        with open(args.out, "w") as f:
            f.write(js)
    return 0 if (res.get("skipped") or res.get("compile_s") is not None) else 1


if __name__ == "__main__":
    sys.exit(main())
