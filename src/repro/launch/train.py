"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Runs the production Trainer (checkpoint/restart, straggler tracking) on the
local devices with the smoke-scale config by default, or lowers the full
config when ``--dry-run`` is given (no allocation).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full arch config (default: smoke-scale)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--spls", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config
    from repro.data.pipeline import DataConfig
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke()
        cfg = dataclasses.replace(cfg, remat=False)
    if args.spls and cfg.has_attn:
        from repro.core.spls import SPLSConfig
        cfg = dataclasses.replace(cfg, spls=SPLSConfig(
            enabled=True, k_ratio=0.2, s_threshold=0.6, f_threshold=2,
            window=4, causal=cfg.causal))

    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch,
        input_mode=cfg.input_mode, d_model=cfg.d_model)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, peak_lr=args.lr,
                         n_micro=args.n_micro)
    out = Trainer(cfg, tcfg, data_cfg).run()
    print(json.dumps(out["metrics"][-3:], indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
