"""Accuracy-vs-sparsity validation (the <=1% loss claim, Sec. V-B).

The paper fine-tunes BERT/GPT on GLUE/WikiText; offline we validate the
claim's *mechanism* on a trainable proxy: a small causal LM on the
deterministic-Markov synthetic task, trained dense and with SPLS at the
paper's hyper-parameters.  The deliverable is the accuracy delta at the
measured computation reduction.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.spls import SPLSConfig
from repro.data.pipeline import DataConfig
from repro.runtime import Trainer, TrainerConfig

STEPS = 150


def _train(spls: SPLSConfig) -> dict:
    cfg = ArchConfig(
        name="acc-bench", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=256, vocab_size=64, period=(BlockCfg(),),
        remat=False, spls=spls)
    data = DataConfig(vocab_size=64, seq_len=64, global_batch=8, seed=7)
    t = Trainer(cfg, TrainerConfig(total_steps=STEPS, log_every=25,
                                   peak_lr=2e-3, warmup_steps=20), data)
    out = t.run()
    last = out["metrics"][-1]
    return {"loss": round(last["loss"], 4),
            "accuracy": round(last["accuracy"], 4)}


def run():
    rows = []
    dense = _train(SPLSConfig(enabled=False))
    rows.append((f"accuracy/dense_{STEPS}steps", 0.0, dense))
    for s, k in ((0.4, 0.25), (0.6, 0.12)):
        spls = SPLSConfig(enabled=True, k_ratio=k, s_threshold=s,
                          f_threshold=2, window=8, causal=True)
        got = _train(spls)
        got["acc_delta_vs_dense"] = round(got["accuracy"] - dense["accuracy"], 4)
        rows.append((f"accuracy/spls_s{s}_k{k}", 0.0, got))
    rows.append(("accuracy/paper_reference", 0.0,
                 {"claim": "<=1% accuracy loss at 51.7% comp. reduction"}))
    return rows
