"""Fig. 15: overall computation reduction + component-wise breakdown.

Runs the full SPLS pipeline on transformer activations at the paper's three
sequence lengths (GLUE=128, SQuAD=384, CLOTH/attention=512) and reports the
exact FLOPs reductions from the plan masks, plus the paper's headline
numbers for reference (51.7% overall; QKV 65.66% / attn 94.65% / FFN
50.33% at <=1% loss).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import SPLSConfig, build_plan, reduction_report
from .common import time_call


def _activations(key, B, L, D, correlated: bool):
    """iid gaussian vs. language-like locally-correlated activations.

    The paper's premise (Sec. II-B) is that *neighboring tokens carry
    similar semantics*; natural text exhibits strong local correlation in
    embedding space.  We model it as a phrase-structured AR(1) walk:
    within phrases of ~6 tokens, successive embeddings keep rho=0.92
    correlation; phrase boundaries resample.  iid rows are the adversarial
    lower bound (no similarity to find).
    """
    if not correlated:
        return jax.random.normal(key, (B, L, D))
    k1, k2, k3 = jax.random.split(key, 3)
    eps = jax.random.normal(k1, (B, L, D))
    boundary = jax.random.bernoulli(k2, 1.0 / 6.0, (B, L))
    rho = jnp.where(boundary, 0.0, 0.92)

    def step(prev, inp):
        e, r = inp
        cur = r[:, None] * prev + jnp.sqrt(1 - r[:, None] ** 2) * e
        return cur, cur

    _, xs = jax.lax.scan(step, eps[:, 0], (eps.swapaxes(0, 1),
                                           rho.swapaxes(0, 1)))
    return xs.swapaxes(0, 1)


def run():
    rows = []
    D, H = 256, 8
    d_ff = 4 * D
    cfg = SPLSConfig(enabled=True, k_ratio=0.10, s_threshold=0.55,
                     f_threshold=3, window=8, causal=False)
    for L in (128, 384, 512):
        for corr in (True, False):
            key = jax.random.PRNGKey(L)
            x = _activations(key, 4, L, D, corr)
            wq = jax.random.normal(jax.random.PRNGKey(1), (D, D)) * D ** -0.5
            wk = jax.random.normal(jax.random.PRNGKey(2), (D, D)) * D ** -0.5
            plan_fn = jax.jit(lambda x_: build_plan(x_, wq, wk, H, cfg))
            us = time_call(plan_fn, x)
            plan = plan_fn(x)
            rep = {k: float(v) for k, v in
                   reduction_report(plan, D, d_ff, causal=False).items()}
            tag = "lang-like" if corr else "iid"
            rows.append((f"reduction/L{L}/{tag}", us, {
                "overall": round(rep["overall_reduction"], 4),
                "qkv": round(rep["qkv_reduction"], 4),
                "attention": round(rep["attention_reduction"], 4),
                "ffn": round(rep["ffn_reduction"], 4),
                "overhead_frac": round(rep["overhead_fraction"], 4),
            }))
    rows.append(("reduction/paper_reference", 0.0, {
        "overall": 0.517, "qkv": 0.6566, "attention": 0.9465, "ffn": 0.5033}))
    return rows
