"""Roofline table assembly: reads results/dryrun.jsonl (written by
repro.launch.dryrun_all) and reports the three terms + bottleneck per
(arch x shape x mesh) cell."""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun.jsonl"


def load_cells(path=RESULTS):
    cells = {}
    if not Path(path).exists():
        return cells
    for line in Path(path).read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        key = (r.get("arch"), r.get("shape"), r.get("mesh"),
               r.get("spls", False))
        cells[key] = r  # last write wins (re-runs supersede)
    return cells


def run():
    rows = []
    cells = load_cells()
    if not cells:
        return [("roofline/missing", 0.0,
                 {"note": "run repro.launch.dryrun_all first"})]
    n_ok = n_skip = n_err = 0
    for (arch, shape, mesh, spls), r in sorted(cells.items()):
        tag = f"roofline/{mesh}/{arch}/{shape}" + ("+spls" if spls else "")
        if r.get("skipped"):
            n_skip += 1
            rows.append((tag, 0.0, {"skipped": r.get("reason", "")}))
            continue
        if "error" in r:
            n_err += 1
            rows.append((tag, 0.0, {"ERROR": r["error"][:120]}))
            continue
        n_ok += 1
        rl = r["roofline"]
        dom_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        rows.append((tag, r.get("compile_s", 0) * 1e6, {
            "compute_s": round(rl["compute_s"], 4),
            "memory_s": round(rl["memory_s"], 4),
            "collective_s": round(rl["collective_s"], 4),
            "dominant": rl["dominant"],
            "roofline_fraction": round(rl["compute_s"] / dom_s, 4),
            "model_flops_ratio": round(r.get("model_flops_ratio") or 0, 4),
        }))
    rows.append(("roofline/summary", 0.0,
                 {"ok": n_ok, "skipped": n_skip, "errors": n_err}))
    return rows
