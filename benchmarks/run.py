"""Benchmark driver -- one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Usage:
  PYTHONPATH=src python -m benchmarks.run [--only reduction quantization ...]
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

MODULES = {
    "reduction": "Fig 15  computation reduction breakdown",
    "quantization": "Figs 7/17/18 + Table III  HLog vs PoT vs APoT",
    "thresholds": "Figs 16/19  s/window/f sweeps",
    "throughput": "Fig 20 + Table IV  cycle/energy model",
    "kernels": "Pallas kernel validation + timing",
    "accuracy": "Sec V-B  accuracy-vs-sparsity proxy",
    "roofline": "Dry-run roofline table (reads results/dryrun.jsonl)",
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {sorted(MODULES)}")
    args = ap.parse_args(argv)
    names = args.only or list(MODULES)

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},"
                      f"\"{json.dumps(derived, default=str)}\"")
                sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name}/FAILED,0,\"{traceback.format_exc(limit=3)!r}\"")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
