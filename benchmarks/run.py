"""Benchmark driver -- one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and persists every module's rows
as a ``BENCH_<module>.json`` artifact at the repo root (schema: one
``{"benchmark", "schema_version", "rows": [{name, us_per_call,
derived}]}`` object per module), so each PR leaves a machine-readable
perf trajectory next to the prose claims (ROADMAP item 5).  The
``throughput`` module additionally writes ``BENCH_serving.json`` -- the
telemetry-derived serving report (see
:mod:`repro.observability.report`).  Usage:
  PYTHONPATH=src python -m benchmarks.run [--only reduction ...]
  [--no-artifacts]
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback
from pathlib import Path

MODULES = {
    "reduction": "Fig 15  computation reduction breakdown",
    "quantization": "Figs 7/17/18 + Table III  HLog vs PoT vs APoT",
    "thresholds": "Figs 16/19  s/window/f sweeps",
    "throughput": "Fig 20 + Table IV  cycle/energy model",
    "kernels": "Pallas kernel validation + timing",
    "accuracy": "Sec V-B  accuracy-vs-sparsity proxy",
    "roofline": "Dry-run roofline table (reads results/dryrun.jsonl)",
}

REPO_ROOT = Path(__file__).resolve().parents[1]

ARTIFACT_SCHEMA_VERSION = 1


def write_artifact(name: str, rows) -> Path:
    """Persist one module's rows as BENCH_<name>.json at the repo root."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    payload = {
        "benchmark": name,
        "schema_version": ARTIFACT_SCHEMA_VERSION,
        "rows": [{"name": rn, "us_per_call": us, "derived": d}
                 for rn, us, d in rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {sorted(MODULES)}")
    ap.add_argument("--no-artifacts", action="store_true",
                    help="print CSV only; skip BENCH_*.json files")
    args = ap.parse_args(argv)
    names = args.only or list(MODULES)

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
            rows = list(mod.run())
            for row_name, us, derived in rows:
                print(f"{row_name},{us:.1f},"
                      f"\"{json.dumps(derived, default=str)}\"")
                sys.stdout.flush()
            if not args.no_artifacts:
                path = write_artifact(name, rows)
                print(f"# wrote {path}", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name}/FAILED,0,\"{traceback.format_exc(limit=3)!r}\"")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
