"""Figs. 7/17/18 + Table III: HLog vs PoT vs APoT.

Reports (a) projection error on int8-quantized gaussian data, (b) Q
sparsity and (c) K sparsity under each quantization method at fixed (k, s),
(d) similarity fidelity -- rank correlation between predicted and true
attention scores -- and (e) the Table III area/power entries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SPLSConfig, build_plan, plan_stats,
                        quantize_dequantize)
from .common import time_call

# Table III (28nm synthesis, from the paper)
TABLE_III = {
    "sanger_4bit": {"area_mm2": 0.23, "power_mw": 81.70},
    "fact_pot": {"area_mm2": 0.14, "power_mw": 37.98},
    "enhance_apot": {"area_mm2": 0.26, "power_mw": 80.76},
    "esact_hlog": {"area_mm2": 0.17, "power_mw": 48.21},
}


def _spearman(a: np.ndarray, b: np.ndarray) -> float:
    ra = np.argsort(np.argsort(a))
    rb = np.argsort(np.argsort(b))
    ra = ra - ra.mean()
    rb = rb - rb.mean()
    return float((ra * rb).sum() / np.sqrt((ra ** 2).sum() * (rb ** 2).sum()))


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8192,))

    for m in ("pot", "apot", "hlog"):
        err = float(jnp.mean(jnp.abs(quantize_dequantize(x, m) - x)))
        rows.append((f"quant/proj_error/{m}", 0.0, {"mae": round(err, 5)}))

    # sparsity + fidelity at fixed (k, s) on a small attention workload
    D, H, L = 128, 8, 128
    xx = jax.random.normal(jax.random.PRNGKey(1), (4, L, D))
    wq = jax.random.normal(jax.random.PRNGKey(2), (D, D)) * D ** -0.5
    wk = jax.random.normal(jax.random.PRNGKey(3), (D, D)) * D ** -0.5
    from repro.core.predict import predicted_attention
    true_pam = np.asarray(
        predicted_attention(xx, wq, wk, H, method="none"))
    for m in ("pot", "apot", "hlog"):
        cfg = SPLSConfig(enabled=True, k_ratio=0.12, s_threshold=0.6,
                         f_threshold=3, window=8, causal=False,
                         quant_method=m)
        fn = jax.jit(lambda x_: build_plan(x_, wq, wk, H, cfg))
        us = time_call(fn, xx)
        stats = {k: float(v) for k, v in plan_stats(fn(xx)).items()}
        pred = np.asarray(predicted_attention(xx, wq, wk, H, method=m))
        rho = _spearman(true_pam.ravel()[::17], pred.ravel()[::17])
        rows.append((f"quant/spls/{m}", us, {
            "q_sparsity": round(stats["q_sparsity"], 4),
            "kv_sparsity": round(stats["kv_sparsity"], 4),
            "similarity_fidelity_rho": round(rho, 4),
        }))

    for name, ap in TABLE_III.items():
        rows.append((f"quant/table3/{name}", 0.0, ap))
    return rows
