"""Figs. 7/17/18 + Table III: HLog vs PoT vs APoT.

Reports (a) projection error on int8-quantized gaussian data, (b) Q
sparsity and (c) K sparsity under each quantization method at fixed (k, s),
(d) similarity fidelity -- rank correlation between predicted and true
attention scores -- (e) the Table III area/power entries, and (f) the
fused predictor matmul (``hlog_qmatmul``) vs its project->materialize->
matmul oracle at **serving shapes**: the chunked-prefill M x K the
predictor actually runs (M = prefill chunk rows, K = d_model, N = the
predicted-head width), so the fused-kernel claim is measured where
serving exercises it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SPLSConfig, build_plan, plan_stats,
                        quantize_dequantize)
from repro.kernels import hlog_qmatmul
from repro.kernels.ref import hlog_qmatmul_ref
from .common import time_call

# Table III (28nm synthesis, from the paper)
TABLE_III = {
    "sanger_4bit": {"area_mm2": 0.23, "power_mw": 81.70},
    "fact_pot": {"area_mm2": 0.14, "power_mw": 37.98},
    "enhance_apot": {"area_mm2": 0.26, "power_mw": 80.76},
    "esact_hlog": {"area_mm2": 0.17, "power_mw": 48.21},
}


def _spearman(a: np.ndarray, b: np.ndarray) -> float:
    ra = np.argsort(np.argsort(a))
    rb = np.argsort(np.argsort(b))
    ra = ra - ra.mean()
    rb = rb - rb.mean()
    return float((ra * rb).sum() / np.sqrt((ra ** 2).sum() * (rb ** 2).sum()))


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8192,))

    for m in ("pot", "apot", "hlog"):
        err = float(jnp.mean(jnp.abs(quantize_dequantize(x, m) - x)))
        rows.append((f"quant/proj_error/{m}", 0.0, {"mae": round(err, 5)}))

    # sparsity + fidelity at fixed (k, s) on a small attention workload
    D, H, L = 128, 8, 128
    xx = jax.random.normal(jax.random.PRNGKey(1), (4, L, D))
    wq = jax.random.normal(jax.random.PRNGKey(2), (D, D)) * D ** -0.5
    wk = jax.random.normal(jax.random.PRNGKey(3), (D, D)) * D ** -0.5
    from repro.core.predict import predicted_attention
    true_pam = np.asarray(
        predicted_attention(xx, wq, wk, H, method="none"))
    for m in ("pot", "apot", "hlog"):
        cfg = SPLSConfig(enabled=True, k_ratio=0.12, s_threshold=0.6,
                         f_threshold=3, window=8, causal=False,
                         quant_method=m)
        fn = jax.jit(lambda x_: build_plan(x_, wq, wk, H, cfg))
        us = time_call(fn, xx)
        stats = {k: float(v) for k, v in plan_stats(fn(xx)).items()}
        pred = np.asarray(predicted_attention(xx, wq, wk, H, method=m))
        rho = _spearman(true_pam.ravel()[::17], pred.ravel()[::17])
        rows.append((f"quant/spls/{m}", us, {
            "q_sparsity": round(stats["q_sparsity"], 4),
            "kv_sparsity": round(stats["kv_sparsity"], 4),
            "similarity_fidelity_rho": round(rho, 4),
        }))

    for name, ap in TABLE_III.items():
        rows.append((f"quant/table3/{name}", 0.0, ap))

    # fused predictor matmul at serving shapes: one chunked-prefill chunk
    # projects (CS, D) activations against (D, H*Dh) predictor weights --
    # BERT-base width (768) at the engine's default chunk sizes.  The
    # fused kernel runs in interpret mode on CPU (bit-accurate, slow);
    # the oracle is the two-pass project -> materialize -> matmul
    # pipeline the fusion removes, timed jitted.
    D = 768
    for CS in (16, 64):
        xq = jnp.round(jax.random.normal(jax.random.PRNGKey(7), (CS, D))
                       * 35).clip(-127, 127)
        wq = jnp.round(jax.random.normal(jax.random.PRNGKey(8), (D, D))
                       * 35).clip(-127, 127)
        ref_fn = jax.jit(hlog_qmatmul_ref)
        us_ref = time_call(ref_fn, xq, wq)
        err = float(jnp.max(jnp.abs(
            hlog_qmatmul(xq, wq, interpret=True) - ref_fn(xq, wq))))
        rows.append((f"quant/hlog_qmatmul_serving/chunk{CS}x{D}", us_ref,
                     {"max_err_vs_fused": err,
                      "timing": "jnp-oracle (CPU); fused kernel "
                                "interpret-checked, timed on TPU only"}))
    return rows
