"""Figs. 16 + 19: similarity-threshold / window-size / FFN-threshold sweeps.

(16) s in {0.1..1.0} x window in {2,4,8,16} -> Q sparsity (accuracy proxy =
     similarity fidelity of recovered rows);
(19) f sweep -> FFN sparsity, showing Q sparsity is decoupled from f.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import SPLSConfig, build_plan, plan_stats


def run():
    rows = []
    D, H, L = 128, 8, 128
    x = jax.random.normal(jax.random.PRNGKey(0), (4, L, D))
    wq = jax.random.normal(jax.random.PRNGKey(1), (D, D)) * D ** -0.5
    wk = jax.random.normal(jax.random.PRNGKey(2), (D, D)) * D ** -0.5

    # Fig 16: s x window -> Q sparsity
    for w in (2, 4, 8, 16):
        for s in (0.2, 0.4, 0.6, 0.8, 1.0):
            cfg = SPLSConfig(enabled=True, k_ratio=0.12, s_threshold=s,
                             f_threshold=3, window=w, causal=False)
            st = plan_stats(build_plan(x, wq, wk, H, cfg))
            rows.append((f"threshold/s{s}_w{w}", 0.0, {
                "q_sparsity": round(float(st["q_sparsity"]), 4)}))

    # Fig 19: f sweep at fixed s -> ffn sparsity; q sparsity decoupled
    for f in (1, 2, 4, 6, 8):
        cfg = SPLSConfig(enabled=True, k_ratio=0.12, s_threshold=0.6,
                         f_threshold=f, window=8, causal=False)
        st = plan_stats(build_plan(x, wq, wk, H, cfg))
        rows.append((f"threshold/f{f}", 0.0, {
            "ffn_sparsity": round(float(st["ffn_sparsity"]), 4),
            "q_sparsity": round(float(st["q_sparsity"]), 4)}))
    return rows
