"""Shared benchmark helpers: timing + the smoke-scale ESACT workload."""

from __future__ import annotations

import time
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockCfg
from repro.core.spls import SPLSConfig


def time_call(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (CPU, jitted fns)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def bert_workload(L: int = 128, B: int = 4, **spls_kw) -> Tuple[ArchConfig, dict]:
    """CPU-scale stand-in for the paper's BERT-Base benchmark setup."""
    spls = SPLSConfig(enabled=True, k_ratio=0.12, s_threshold=0.6,
                      f_threshold=6, window=8, causal=False, **spls_kw)
    cfg = ArchConfig(
        name="bert-bench", n_layers=2, d_model=128, n_heads=8, n_kv_heads=8,
        head_dim=16, d_ff=512, vocab_size=1024,
        period=(BlockCfg(mixer="attn"),), causal=False,
        ffn_activation="gelu_mlp", remat=False, spls=spls)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, L, cfg.d_model))
    return cfg, {"x": x}
