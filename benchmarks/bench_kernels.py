"""Kernel microbenchmarks: Pallas (interpret on CPU; compiled on TPU) vs
the pure-jnp oracle, plus max-abs-error per shape."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (flash_attention, flash_decode, hlog_qmatmul,
                           local_similarity_dist)
from repro.kernels import ref
from .common import time_call


def run():
    rows = []
    # hlog matmul
    for M, K, N in ((256, 256, 256), (512, 512, 512)):
        xq = jnp.round(jax.random.normal(jax.random.PRNGKey(0), (M, K)) * 35
                       ).clip(-127, 127)
        wq = jnp.round(jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 35
                       ).clip(-127, 127)
        ref_fn = jax.jit(ref.hlog_qmatmul_ref)
        us_ref = time_call(ref_fn, xq, wq)
        err = float(jnp.max(jnp.abs(
            hlog_qmatmul(xq, wq, interpret=True) - ref_fn(xq, wq))))
        rows.append((f"kernel/hlog_qmatmul/{M}x{K}x{N}", us_ref,
                     {"max_err_vs_oracle": err, "timing": "jnp-oracle (CPU)"}))

    # flash attention
    for L in (256, 512):
        q, k, v = (jax.random.normal(jax.random.PRNGKey(s), (1, 4, L, 64))
                   for s in (2, 3, 4))
        ref_fn = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
        us_ref = time_call(ref_fn, q, k, v)
        err = float(jnp.max(jnp.abs(
            flash_attention(q, k, v, interpret=True) - ref_fn(q, k, v))))
        rows.append((f"kernel/flash_attention/L{L}", us_ref,
                     {"max_err_vs_oracle": round(err, 8)}))

    # flash decode (one token vs a 2k cache)
    q = jax.random.normal(jax.random.PRNGKey(6), (2, 2, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(7), (2, 2, 2048, 64))
    v = jax.random.normal(jax.random.PRNGKey(8), (2, 2, 2048, 64))
    pos = jnp.asarray([2000, 511])
    ref_fn = jax.jit(lambda a, b, c, p: ref.flash_decode_ref(a, b, c, p))
    us_ref = time_call(ref_fn, q, k, v, pos)
    err = float(jnp.max(jnp.abs(
        flash_decode(q, k, v, pos, block_k=512, interpret=True)
        - ref_fn(q, k, v, pos))))
    rows.append(("kernel/flash_decode/S2048", us_ref,
                 {"max_err_vs_oracle": round(err, 8)}))

    # local similarity
    spa = jax.random.normal(jax.random.PRNGKey(5), (2, 4, 64, 512))
    ref_fn = jax.jit(lambda s: ref.local_similarity_ref(s, 8))
    us_ref = time_call(ref_fn, spa)
    err = float(jnp.max(jnp.abs(
        local_similarity_dist(spa, w=8, interpret=True) - ref_fn(spa))))
    rows.append(("kernel/local_similarity/64x512", us_ref,
                 {"max_err_vs_oracle": round(err, 6)}))
    return rows
