"""Kernel microbenchmarks: Pallas (interpret on CPU; compiled on TPU) vs
the pure-jnp oracle, plus max-abs-error per shape; and the attention
backend registry timed dense-vs-pallas-vs-sparse on one workload."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (flash_attention, flash_decode, hlog_qmatmul,
                           local_similarity_dist)
from repro.kernels import ref
from .common import time_call


def _backend_rows():
    """Registry comparison: every forward backend on the same workload,
    dense and under an SPLS plan (timings vs the xla_dense baseline; the
    Pallas rows run in interpret mode on CPU -- numbers are for parity,
    the speed story needs a TPU)."""
    from repro.configs.base import ArchConfig, BlockCfg
    from repro.core.spls import SPLSConfig, SparsityPlan, build_plan
    from repro.models import available_backends, get_backend

    B, H, L, Dh = 1, 4, 256, 64
    D = H * Dh
    cfg = ArchConfig(name="bench", d_model=D, n_heads=H, n_kv_heads=H,
                     head_dim=Dh, causal=True)
    ks = jax.random.split(jax.random.PRNGKey(9), 6)
    q = jax.random.normal(ks[0], (B, H, 1, L, Dh))
    k = jax.random.normal(ks[1], (B, H, L, Dh))
    v = jax.random.normal(ks[2], (B, H, L, Dh))
    plan = build_plan(jax.random.normal(ks[3], (B, L, D)),
                      jax.random.normal(ks[4], (D, D)) * 0.1,
                      jax.random.normal(ks[5], (D, D)) * 0.1,
                      H, SPLSConfig(k_ratio=0.12, s_threshold=0.8,
                                    window=8))
    plan = SparsityPlan(*(t.reshape(B, H, 1, *t.shape[2:])
                          if t.ndim > 2 else t for t in plan))

    rows = []
    interp = jax.default_backend() != "tpu"
    names = sorted(available_backends(decode=False),
                   key=lambda n: n != "xla_dense")  # baseline first
    for with_plan in (False, True):
        pl_ = plan if with_plan else None
        base = None
        for name in names:
            fn = get_backend(name)
            call = jax.jit(lambda q_, k_, v_, fn=fn: fn(
                cfg, q_, k_, v_, plan=pl_, q_capacity=L // 2 if pl_ else None))
            us = time_call(call, q, k, v)
            out = call(q, k, v)
            if base is None:
                base = out
            tag = "spls" if with_plan else "dense"
            rows.append((f"kernel/attn_backend/{name}/{tag}/L{L}", us,
                         {"max_err_vs_xla_dense":
                          round(float(jnp.max(jnp.abs(out - base))), 6),
                          "timing": ("interpret (CPU)"
                                     if interp and "pallas" in name
                                     else "jit")}))
    return rows


def run():
    rows = []
    # hlog matmul
    for M, K, N in ((256, 256, 256), (512, 512, 512)):
        xq = jnp.round(jax.random.normal(jax.random.PRNGKey(0), (M, K)) * 35
                       ).clip(-127, 127)
        wq = jnp.round(jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 35
                       ).clip(-127, 127)
        ref_fn = jax.jit(ref.hlog_qmatmul_ref)
        us_ref = time_call(ref_fn, xq, wq)
        err = float(jnp.max(jnp.abs(
            hlog_qmatmul(xq, wq, interpret=True) - ref_fn(xq, wq))))
        rows.append((f"kernel/hlog_qmatmul/{M}x{K}x{N}", us_ref,
                     {"max_err_vs_oracle": err, "timing": "jnp-oracle (CPU)"}))

    # flash attention
    for L in (256, 512):
        q, k, v = (jax.random.normal(jax.random.PRNGKey(s), (1, 4, L, 64))
                   for s in (2, 3, 4))
        ref_fn = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
        us_ref = time_call(ref_fn, q, k, v)
        err = float(jnp.max(jnp.abs(
            flash_attention(q, k, v, interpret=True) - ref_fn(q, k, v))))
        rows.append((f"kernel/flash_attention/L{L}", us_ref,
                     {"max_err_vs_oracle": round(err, 8)}))

    # flash decode (one token vs a 2k cache)
    q = jax.random.normal(jax.random.PRNGKey(6), (2, 2, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(7), (2, 2, 2048, 64))
    v = jax.random.normal(jax.random.PRNGKey(8), (2, 2, 2048, 64))
    pos = jnp.asarray([2000, 511])
    ref_fn = jax.jit(lambda a, b, c, p: ref.flash_decode_ref(a, b, c, p))
    us_ref = time_call(ref_fn, q, k, v, pos)
    err = float(jnp.max(jnp.abs(
        flash_decode(q, k, v, pos, block_k=512, interpret=True)
        - ref_fn(q, k, v, pos))))
    rows.append(("kernel/flash_decode/S2048", us_ref,
                 {"max_err_vs_oracle": round(err, 8)}))

    # local similarity
    spa = jax.random.normal(jax.random.PRNGKey(5), (2, 4, 64, 512))
    ref_fn = jax.jit(lambda s: ref.local_similarity_ref(s, 8))
    us_ref = time_call(ref_fn, spa)
    err = float(jnp.max(jnp.abs(
        local_similarity_dist(spa, w=8, interpret=True) - ref_fn(spa))))
    rows.append(("kernel/local_similarity/64x512", us_ref,
                 {"max_err_vs_oracle": round(err, 6)}))

    # gathered matmul: double-buffered vs serialized row-DMA gather.
    # Both variants are bitwise equal to the XLA x[perm] @ w oracle; the
    # timed pair isolates what the two-semaphore DMA pipeline buys.  On
    # CPU both run interpret-mode (parity only); on TPU they compile and
    # the timing delta is the measurement ROADMAP carries forward.  The
    # dispatch is wrapped in jax.profiler.TraceAnnotation
    # ("gathered_matmul/{buffered,serialized}"), so a jax.profiler trace
    # of this benchmark names each variant on the TPU timeline.
    from repro.kernels import gathered_matmul

    L, D, F, C = 512, 256, 256, 128
    x = jax.random.normal(jax.random.PRNGKey(10), (L, D))
    w = jax.random.normal(jax.random.PRNGKey(11), (D, F))
    perm = jax.random.randint(jax.random.PRNGKey(12), (C,), 0, L)
    interp = jax.default_backend() != "tpu"
    base = jax.jit(lambda a, b, p: a[p] @ b)(x, w, perm)
    gm_us = {}
    for db in (True, False):
        def call(a, b, p, db=db):
            return gathered_matmul(a, b, p, interpret=interp,
                                   double_buffer=db)
        us = time_call(call, x, w, perm)
        tag = "buffered" if db else "serialized"
        gm_us[tag] = us
        err = float(jnp.max(jnp.abs(call(x, w, perm) - base)))
        rows.append((f"kernel/gathered_matmul/{tag}/C{C}_D{D}_F{F}", us,
                     {"max_err_vs_oracle": err,
                      "timing": "interpret (CPU)" if interp else "jit"}))
    rows.append(("kernel/gathered_matmul/dma_overlap_summary", 0.0, {
        "us_buffered": round(gm_us["buffered"], 1),
        "us_serialized": round(gm_us["serialized"], 1),
        "overlap_speedup_x": round(
            gm_us["serialized"] / max(gm_us["buffered"], 1e-9), 3),
        "timing": "interpret (CPU)" if interp else "jit"}))

    rows.extend(_backend_rows())
    return rows
