"""Fig. 20 + Table IV: cycle-model throughput decomposition and the
attention-level energy-efficiency comparison vs SpAtten / Sanger -- plus a
*measured* serving comparison: tokens/sec and pages-in-use for the dense
fixed-slot engine vs the block-pool paged engine vs paged+SPLS page
pruning on the BERT-Base (smoke-scale) config.  The derived
``req_per_mb`` column is the acceptance metric: concurrent requests per
MB of KV pool actually needed (paged+SPLS > paged > dense)."""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.perfmodel import (attention_level_comparison, energy_efficiency,
                             speedup_breakdown)

# paper-measured SPLS sparsity (Fig. 15 averages)
PAPER_REDUCTIONS = {"qkv": 0.6566, "attention": 0.9465, "ffn": 0.5033}

# measured serving workload (CPU smoke scale)
_N_REQ, _SLOTS, _PROMPT, _MAX_NEW, _PS = 8, 4, 48, 8, 8


def _bert_serving_cfg(spls: bool):
    from repro.configs.bert_base_esact import CONFIG
    from repro.core.spls import SPLSConfig

    cfg = dataclasses.replace(CONFIG.smoke(), remat=False, causal=True)
    spls_cfg = SPLSConfig(enabled=spls, k_ratio=0.12, s_threshold=0.6,
                          f_threshold=2, window=4, causal=True)
    return dataclasses.replace(cfg, spls=spls_cfg)


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _measure_engine(mode: str, telemetry: bool = True):
    """mode: dense | paged | paged_spls | paged_chunked |
    paged_spls_chunked.  The ``*_chunked`` variants prefill long prompts
    in 16-token chunks (interleaved with decode); ``paged_spls_chunked``
    is the progressive-SPLS serving path -- the plan streams per chunk and
    kept KV columns compact at end of prefill.  Returns ``(us, derived,
    engine, outputs)``; ``telemetry=False`` measures the no-op-sink
    engine for the overhead row."""
    from repro.models import init_params
    from repro.serving import (PagedServingEngine, Request, ServeConfig,
                               ServingEngine)

    chunked = mode.endswith("_chunked")
    spls = mode.startswith("paged_spls")
    cfg = _bert_serving_cfg(spls)
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = _PROMPT + _MAX_NEW + _PS
    scfg = ServeConfig(n_slots=_SLOTS, max_len=max_len, page_size=_PS,
                       attn_backend=None if mode == "dense"
                       else "xla_paged_decode",
                       prefill_chunk=16 if chunked else 64,
                       spls_page_prune=spls, spls_prune_vote=1.0,
                       telemetry=telemetry)
    eng = (ServingEngine if mode == "dense"
           else PagedServingEngine)(cfg, params, scfg)
    reqs = []
    for i in range(_N_REQ):
        prompt = jax.random.randint(jax.random.PRNGKey(200 + i), (_PROMPT,),
                                    0, cfg.vocab_size)
        r = Request(rid=i, prompt=prompt, max_new_tokens=_MAX_NEW)
        reqs.append(r)
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_until_drained(max_ticks=2000)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.output) for r in reqs)
    assert all(r.done for r in reqs)

    if mode == "dense":
        kv_bytes = _tree_bytes(eng.cache)           # n_slots x max_len slab
        pages = None
        saved = {"qkv": 0.0, "attn": 0.0, "ffn": 0.0, "kv": 0.0}
    else:
        # the SPLS predictor cache is page-parallel pool memory: charge it
        # (int8 codes + per-token scale since the planner unification --
        # _tree_bytes naturally reports the reduced footprint)
        pool_bytes = _tree_bytes(eng.cache)
        if eng.pred_cache is not None:
            pool_bytes += _tree_bytes(eng.pred_cache)
        page_bytes = pool_bytes / eng.pool.n_pages
        kv_bytes = int(eng.stats["peak_pages"] * page_bytes)
        pages = eng.stats["peak_pages"]
        saved = eng.stats["flops_saved_pct"]
    out = {"tok_s": round(tokens / dt, 1),
           "kv_mb": round(kv_bytes / 1e6, 4),
           "concurrent": _SLOTS,
           "req_per_mb": round(_SLOTS / (kv_bytes / 1e6), 2),
           # lifetime prefill-compute savings (scheduler accounting);
           # dense compute executes everything, so these stay 0.0 until a
           # packed compute backend is active
           "flops_saved_qkv_pct": round(saved["qkv"], 1),
           "flops_saved_attn_pct": round(saved["attn"], 1),
           "flops_saved_ffn_pct": round(saved["ffn"], 1),
           "flops_saved_kv_pct": round(saved.get("kv", 0.0), 1)}
    if pages is not None:
        out["pages_in_use_peak"] = pages
    return dt * 1e6, out, eng, [list(r.output) for r in reqs]


# end-to-end sparse prefill comparison (serving width): bert-smoke
# architecture widened to a serving-shaped d_model/d_ff so the packed
# matmul savings are measurable above CPU dispatch noise
_PK_PROMPT, _PK_CHUNK, _PK_REQS, _PK_NEW = 128, 32, 6, 2


def _measure_packed_prefill(compute_backend: str,
                            vote_horizon=None):
    """Prefill-heavy chunked+SPLS serving run; compute_backend "dense" is
    the baseline, "packed_xla" the end-to-end sparse path (same engine,
    same plan, only the compute execution differs).  ``vote_horizon=1``
    adds the horizon-finalized prune vote: a chunk's own columns that
    miss the cross-head bar on their own plan block skip the K/V
    projection entirely (core.planner; bounded divergence from the
    end-of-prefill vote, measured here as flops_saved_kv_pct > 0)."""
    from repro.models import init_params
    from repro.serving import PagedServingEngine, Request, ServeConfig

    cfg = _bert_serving_cfg(True)
    cfg = dataclasses.replace(cfg, d_model=256, d_ff=1024, head_dim=64,
                              spls=dataclasses.replace(cfg.spls,
                                                       s_threshold=0.95))
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(n_slots=3, max_len=_PK_PROMPT + _PK_NEW + _PS,
                       page_size=_PS, prefill_chunk=_PK_CHUNK,
                       attn_backend="xla_paged_decode", spls_prune_vote=1.0,
                       compute_backend=compute_backend, capacity_margin=1.0,
                       vote_horizon=vote_horizon)
    eng = PagedServingEngine(cfg, params, scfg)

    def batch(rid0, n, max_new):
        reqs = [Request(rid=rid0 + i, prompt=jax.random.randint(
            jax.random.PRNGKey(300 + rid0 + i), (_PK_PROMPT,),
            0, cfg.vocab_size), max_new_tokens=max_new) for i in range(n)]
        for r in reqs:
            eng.submit(r)
        return reqs

    # warmup: converge the capacity controller's EMA and compile the
    # bucket variants it settles on (16 chunks; a residual one-off
    # compile in the timed window stays possible if the estimate crosses
    # a bucket boundary mid-measurement, but the dense baseline has one
    # variant and the same exposure to first-call compiles)
    batch(900, 4, 1)
    eng.run_until_drained(max_ticks=2000)
    reqs = batch(0, _PK_REQS, _PK_NEW)
    t0 = time.perf_counter()
    eng.run_until_drained(max_ticks=2000)
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    tokens = sum(len(r.output) for r in reqs)
    saved = eng.stats["flops_saved_pct"]
    return dt * 1e6, {"tok_s": round(tokens / dt, 1),
                      "flops_saved_qkv_pct": round(saved["qkv"], 1),
                      "flops_saved_attn_pct": round(saved["attn"], 1),
                      "flops_saved_ffn_pct": round(saved["ffn"], 1),
                      "flops_saved_kv_pct": round(saved.get("kv", 0.0), 1)
                      }, eng, dt


def run():
    rows = []
    # BERT-Base @ L=512 (the paper's calibration workload is L=128 D=768)
    for L in (128, 512):
        sb = speedup_breakdown(L, 768, 12, 3072, PAPER_REDUCTIONS)
        rows.append((f"throughput/breakdown_L{L}", 0.0, {
            "spls_x": round(sb["spls_speedup"], 3),
            "progressive_x": round(sb["progressive_speedup"], 3),
            "dynamic_x": round(sb["dynamic_speedup"], 3),
            "end_to_end_x": round(sb["end_to_end_speedup"], 3)}))
    rows.append(("throughput/paper_reference", 0.0, {
        "spls_x": 1.59, "progressive_x": 1.18, "dynamic_x": 1.04,
        "asic_vs_v100_x": 2.42, "end_to_end_vs_v100_x": 4.72}))

    ee = energy_efficiency(512, 768, 12, 3072, PAPER_REDUCTIONS)
    rows.append(("energy/end_to_end", 0.0,
                 {k: round(v, 3) for k, v in ee.items()}))
    rows.append(("energy/paper_reference", 0.0, {"tops_per_w": 3.27}))

    ac = attention_level_comparison(512, 768, 12,
                                    PAPER_REDUCTIONS["attention"])
    rows.append(("energy/attention_level", 0.0,
                 {k: round(v, 3) for k, v in ac.items()}))
    rows.append(("energy/attention_paper_reference", 0.0, {
        "energy_eff_gops_w": 6677, "vs_spatten": 2.95, "vs_sanger": 2.26}))

    # measured serving: dense slab vs paged pool vs paged+SPLS pruning,
    # plus the long-prompt chunked-prefill pair (dense chunked vs the
    # progressive chunked+SPLS path -- the acceptance comparison)
    derived = {}
    outputs = {}
    for mode in ("dense", "paged", "paged_spls", "paged_chunked",
                 "paged_spls_chunked"):
        us, d, _eng, outs = _measure_engine(mode)
        derived[mode] = d
        outputs[mode] = outs
        rows.append((f"serving/{mode}", round(us, 1), d))

    # telemetry overhead: the same progressive-SPLS workload with the
    # no-op sink.  Greedy outputs must match bit-for-bit (telemetry is
    # host-side only; the acceptance invariant).  The main-loop on-run
    # above paid this mode's first-call jit compiles inside its timed
    # window, so compare a matched warm pair instead: off then on again,
    # both reusing the now-populated jit cache (CPU smoke scale is
    # dispatch-dominated, so the delta bounds the TPU overhead above)
    # best-of-2 per arm, alternating, to suppress CPU contention noise
    # (single pairs swing +-5% on a loaded host; the arms measure within
    # noise of each other when run in isolation)
    tok = {True: 0.0, False: 0.0}
    us_off = 0.0
    for arm in (False, True, False, True):
        us_arm, d_arm, _eng_arm, outs_arm = _measure_engine(
            "paged_spls_chunked", telemetry=arm)
        assert outs_arm == outputs["paged_spls_chunked"], \
            "telemetry changed greedy outputs"
        tok[arm] = max(tok[arm], d_arm["tok_s"])
        if not arm:
            us_off = us_arm
    tok_on = tok[True]
    tok_off = tok[False]
    rows.append(("serving/telemetry_overhead", round(us_off, 1), {
        "tok_s_telemetry_on": tok_on,
        "tok_s_telemetry_off": tok_off,
        "overhead_pct": round(100.0 * (1.0 - tok_on / max(tok_off, 1e-9)),
                              2),
        "outputs_bitwise_equal": True}))
    gain = (derived["paged_spls"]["req_per_mb"]
            / max(derived["dense"]["req_per_mb"], 1e-9))
    rows.append(("serving/summary", 0.0, {
        "req_per_mb_dense": derived["dense"]["req_per_mb"],
        "req_per_mb_paged": derived["paged"]["req_per_mb"],
        "req_per_mb_paged_spls": derived["paged_spls"]["req_per_mb"],
        "paged_spls_vs_dense_x": round(gain, 2)}))
    ck, cs = derived["paged_chunked"], derived["paged_spls_chunked"]
    rows.append(("serving/summary_chunked", 0.0, {
        "peak_pages_dense_chunked": ck["pages_in_use_peak"],
        "peak_pages_spls_chunked": cs["pages_in_use_peak"],
        "page_reduction_x": round(ck["pages_in_use_peak"]
                                  / max(cs["pages_in_use_peak"], 1), 2),
        "req_per_mb_dense_chunked": ck["req_per_mb"],
        "req_per_mb_spls_chunked": cs["req_per_mb"],
        "tok_s_dense_chunked": ck["tok_s"],
        "tok_s_spls_chunked": cs["tok_s"]}))

    # end-to-end sparse prefill: same chunked+SPLS engine, dense compute
    # vs packed compute (token-compacted QKV/attention/FFN); the packed
    # row must win tok/s with nonzero qkv AND ffn savings.  The
    # vote_horizon=1 row adds horizon-finalized column votes: the only
    # row where the K/V projection itself runs packed (nonzero
    # flops_saved_kv_pct -- the acceptance metric for the early vote)
    pk = {}
    report_src = None
    for cb, h in (("dense", None), ("packed_xla", None), ("packed_xla", 1)):
        us, d, eng, dt = _measure_packed_prefill(cb, vote_horizon=h)
        tag = cb if h is None else f"{cb}_h{h}"
        pk[tag] = d
        if tag == "packed_xla_h1":
            report_src = (eng, dt)
        rows.append((f"serving/prefill_compute_{tag}", round(us, 1), d))
    rows.append(("serving/summary_packed_prefill", 0.0, {
        "tok_s_dense_compute": pk["dense"]["tok_s"],
        "tok_s_packed_xla": pk["packed_xla"]["tok_s"],
        "packed_vs_dense_x": round(pk["packed_xla"]["tok_s"]
                                   / max(pk["dense"]["tok_s"], 1e-9), 2),
        "flops_saved_qkv_pct": pk["packed_xla"]["flops_saved_qkv_pct"],
        "flops_saved_attn_pct": pk["packed_xla"]["flops_saved_attn_pct"],
        "flops_saved_ffn_pct": pk["packed_xla"]["flops_saved_ffn_pct"],
        "flops_saved_kv_pct_h1": pk["packed_xla_h1"]["flops_saved_kv_pct"],
        "tok_s_packed_xla_h1": pk["packed_xla_h1"]["tok_s"]}))

    # BENCH_serving.json: the schema-versioned serving trajectory
    # artifact (ROADMAP item 5), built from the vote_horizon=1 packed
    # run's telemetry -- the richest row (TTFT/TPOT percentiles, all
    # four flops_saved components, capacity occupancy, pool bytes) --
    # and written to the repo root on every benchmark run
    from pathlib import Path

    from repro.observability import (serving_report, validate_report,
                                     write_report)

    eng, dt = report_src
    # wall_s defaults to time-since-engine-start so throughput covers the
    # same window the telemetry's request records cover (incl. warmup)
    report = serving_report(eng, extra={
        "workload": {"bench": "throughput/packed_prefill_h1",
                     "prompt_len": _PK_PROMPT, "chunk": _PK_CHUNK,
                     "n_requests": _PK_REQS, "max_new": _PK_NEW},
        "telemetry_overhead_pct": rows and next(
            (r[2]["overhead_pct"] for r in rows
             if r[0] == "serving/telemetry_overhead"), None)})
    validate_report(report)
    path = Path(__file__).resolve().parents[1] / "BENCH_serving.json"
    write_report(str(path), report)
    rows.append(("serving/bench_json", 0.0, {
        "path": str(path), "schema_version": report["schema_version"],
        "ttft_p50_ms": report["latency"]["ttft_ms"]["p50"],
        "tpot_p50_ms": report["latency"]["tpot_ms"]["p50"]}))
    return rows
