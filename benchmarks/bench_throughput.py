"""Fig. 20 + Table IV: cycle-model throughput decomposition and the
attention-level energy-efficiency comparison vs SpAtten / Sanger."""

from __future__ import annotations

from repro.perfmodel import (attention_level_comparison, energy_efficiency,
                             speedup_breakdown)

# paper-measured SPLS sparsity (Fig. 15 averages)
PAPER_REDUCTIONS = {"qkv": 0.6566, "attention": 0.9465, "ffn": 0.5033}


def run():
    rows = []
    # BERT-Base @ L=512 (the paper's calibration workload is L=128 D=768)
    for L in (128, 512):
        sb = speedup_breakdown(L, 768, 12, 3072, PAPER_REDUCTIONS)
        rows.append((f"throughput/breakdown_L{L}", 0.0, {
            "spls_x": round(sb["spls_speedup"], 3),
            "progressive_x": round(sb["progressive_speedup"], 3),
            "dynamic_x": round(sb["dynamic_speedup"], 3),
            "end_to_end_x": round(sb["end_to_end_speedup"], 3)}))
    rows.append(("throughput/paper_reference", 0.0, {
        "spls_x": 1.59, "progressive_x": 1.18, "dynamic_x": 1.04,
        "asic_vs_v100_x": 2.42, "end_to_end_vs_v100_x": 4.72}))

    ee = energy_efficiency(512, 768, 12, 3072, PAPER_REDUCTIONS)
    rows.append(("energy/end_to_end", 0.0,
                 {k: round(v, 3) for k, v in ee.items()}))
    rows.append(("energy/paper_reference", 0.0, {"tops_per_w": 3.27}))

    ac = attention_level_comparison(512, 768, 12,
                                    PAPER_REDUCTIONS["attention"])
    rows.append(("energy/attention_level", 0.0,
                 {k: round(v, 3) for k, v in ac.items()}))
    rows.append(("energy/attention_paper_reference", 0.0, {
        "energy_eff_gops_w": 6677, "vs_spatten": 2.95, "vs_sanger": 2.26}))
    return rows
